//! Dynamic batcher: per-route worker threads that coalesce requests into
//! engine-sized batches under a latency window.
//!
//! Routes are keyed by **(robot, route)** so a single coordinator serves
//! many registered robots concurrently — the multi-tenant operating model
//! of the accelerator (one deployment, heterogeneous dynamics queries).
//! Each route is backed by a [`BackendSpec`]: the native f64 workspace
//! engine, the rounded fixed-point engine at a per-robot `QFormat`, the
//! true-integer `i64` engine under a proved shift schedule, a
//! trajectory-rollout route driven through the workspace integrator
//! (on the robot's serving lane — see [`TrajLane`]), a fault-injection
//! chaos route, or (behind the `pjrt` feature) a compiled PJRT artifact.
//! The batching loop is identical either way.
//!
//! Overload behaviour is governed by the QoS layer (see
//! [`super::qos`]): jobs carry a priority class and an optional
//! deadline, admission is bounded per (route, class), batch formation
//! drains `Control` before `Interactive` before `Bulk` and drops
//! expired jobs unexecuted, and a panicking engine evaluation is caught
//! at the batch boundary and counted toward the route's circuit
//! breaker.

use super::qos::{QosClass, QosPolicy, RouteGate, ServeError, SubmitOptions};
use super::registry::RobotRegistry;
use super::stats::{lock_stats, ServeStats, StatsInner};
use crate::dynamics::pool::panic_message;
use crate::model::Robot;
use crate::obs::{ObsHub, RouteStages, Span, Terminal};
use crate::quant::QFormat;
#[cfg(feature = "pjrt")]
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::artifact::ArtifactFn;
use crate::runtime::{ChaosEngine, DynamicsEngine, NativeEngine, QIntEngine, QuantEngine};
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One trajectory request: an initial state plus H torque rows, unrolled
/// server-side through the workspace integrator in a single dispatch.
#[derive(Debug, Clone)]
pub struct TrajRequest {
    /// Initial joint positions (length N).
    pub q0: Vec<f32>,
    /// Initial joint velocities (length N).
    pub qd0: Vec<f32>,
    /// H torque rows, row-major flat (length H·N).
    pub tau: Vec<f32>,
    /// Integration step [s].
    pub dt: f64,
}

/// What a job carries: one step task or one trajectory rollout.
pub enum JobPayload {
    /// Flat f32 operands for a single step task (each of length N).
    Step(Vec<Vec<f32>>),
    /// A trajectory rollout request.
    Traj(TrajRequest),
}

/// One queued request.
pub struct Job {
    /// The request body.
    pub payload: JobPayload,
    /// Priority class (decides the draining lane).
    pub class: QosClass,
    /// Optional deadline relative to `enqueued` [µs]; a job still queued
    /// past it is dropped at batch formation — never executed.
    pub deadline_us: Option<u64>,
    /// When the request entered the coordinator (for latency stats and
    /// deadline accounting).
    pub enqueued: Instant,
    /// Where this job's output goes: an in-process channel
    /// ([`ChannelSink`], the `submit_*` API) or a network connection
    /// (the JSONL server's socket writer). Trajectory workers stream
    /// rows into it mid-horizon.
    pub sink: Box<dyn ResponseSink>,
    /// Trace span stamped as the job moves through the pipeline — the
    /// inert [`Span::disabled`] unless `serve --trace` is on.
    pub span: Span,
}

impl Job {
    /// `Some(waited_us)` when the job's deadline has passed.
    fn expired(&self) -> Option<u64> {
        let deadline = self.deadline_us?;
        let waited = self.enqueued.elapsed().as_micros() as u64;
        (waited >= deadline).then_some(waited)
    }

    /// Terminate this job with an error (consumes the job; `done` is
    /// the sink's exactly-once completion call). The span's terminal
    /// mirrors the error, so every traced job ends in exactly one
    /// terminal stamp no matter which failure path consumed it.
    fn fail(mut self, err: ServeError) {
        self.span.finish(terminal_for(&err));
        self.sink.done(Err(err));
    }
}

/// The trace terminal that mirrors a [`ServeError`].
fn terminal_for(err: &ServeError) -> Terminal {
    match err {
        ServeError::Rejected { .. } => Terminal::Rejected,
        ServeError::Shed { .. } => Terminal::Shed,
        ServeError::Expired { .. } => Terminal::Expired,
        ServeError::Cancelled => Terminal::Cancelled,
        ServeError::ShuttingDown => Terminal::Shutdown,
        ServeError::Engine(_) | ServeError::BadRequest(_) => Terminal::Error,
    }
}

/// Display name of a route for spans and stage-metric labels.
fn route_label(route: Route) -> &'static str {
    match route {
        Route::Step(f) => f.name(),
        Route::Traj => "traj",
    }
}

/// Per-task result: the flat f32 output slice for this task, or a
/// structured [`ServeError`] naming why it was refused / dropped /
/// failed.
pub type JobResult = Result<Vec<f32>, ServeError>;

/// Per-job egress abstraction — the refactor seam between the batcher
/// and whoever is waiting for the answer. The in-process path
/// ([`ChannelSink`]) buffers chunks and sends one [`JobResult`]; the
/// network path writes `chunk` frames straight to the client socket as
/// the integrator produces rows.
///
/// Contract: `accepted` fires at most once (after admission, before any
/// chunk); `begin_stream` fires at most once, only on streamed
/// (trajectory) jobs, before the first chunk; `chunk` fires zero or
/// more times; `done` fires exactly once and nothing follows it.
pub trait ResponseSink: Send {
    /// The job passed admission and was enqueued (the wire `ack`).
    fn accepted(&mut self) {}
    /// A streamed response is starting: expect up to `rows` chunks of
    /// length `2·half` each (`q_t ‖ q̇_t`; `half` = robot DOF). Sizing
    /// hint only — a failing rollout may end the stream early.
    fn begin_stream(&mut self, _rows: usize, _half: usize) {}
    /// One flat f32 payload fragment (a whole step answer, or one
    /// trajectory row).
    fn chunk(&mut self, data: &[f32]);
    /// Terminal outcome. No calls follow.
    fn done(&mut self, result: Result<(), ServeError>);
    /// Whether the consumer is still listening. Trajectory workers poll
    /// this between integration steps and cancel the remaining horizon
    /// when it turns false (a disconnected network client stops costing
    /// integrator time mid-request).
    fn alive(&self) -> bool {
        true
    }
}

/// [`ResponseSink`] backing the in-process `submit_*` API: accumulates
/// chunks and answers one [`JobResult`] on the paired [`Receiver`].
/// Streamed trajectory rows (`q_t ‖ q̇_t` per chunk) are de-interleaved
/// back into the legacy `[H q-rows | H q̇-rows]` flat layout, so callers
/// of [`Coordinator::submit_traj`] see exactly the pre-streaming wire
/// format.
pub struct ChannelSink {
    tx: Sender<JobResult>,
    buf: Vec<f32>,
    /// `Some((rows_hint, half))` once `begin_stream` fired.
    stream: Option<(usize, usize)>,
}

impl ChannelSink {
    /// New sink plus the receiver its single [`JobResult`] arrives on.
    pub fn new() -> (ChannelSink, Receiver<JobResult>) {
        let (tx, rx) = channel();
        (ChannelSink { tx, buf: Vec::new(), stream: None }, rx)
    }
}

impl ResponseSink for ChannelSink {
    fn begin_stream(&mut self, rows: usize, half: usize) {
        self.stream = Some((rows, half));
        self.buf.reserve(2 * rows * half);
    }
    fn chunk(&mut self, data: &[f32]) {
        self.buf.extend_from_slice(data);
    }
    fn done(&mut self, result: Result<(), ServeError>) {
        let msg = match result {
            Ok(()) => {
                let buf = std::mem::take(&mut self.buf);
                match self.stream {
                    // De-interleave the streamed rows; the *actual*
                    // chunk count (not the hint) decides H.
                    Some((_, half)) if half > 0 && buf.len() % (2 * half) == 0 => {
                        let h = buf.len() / (2 * half);
                        let mut flat = vec![0.0f32; buf.len()];
                        for t in 0..h {
                            let row = &buf[t * 2 * half..(t + 1) * 2 * half];
                            flat[t * half..(t + 1) * half].copy_from_slice(&row[..half]);
                            flat[(h + t) * half..(h + t + 1) * half]
                                .copy_from_slice(&row[half..]);
                        }
                        Ok(flat)
                    }
                    _ => Ok(buf),
                }
            }
            Err(e) => Err(e),
        };
        let _ = self.tx.send(msg);
    }
}

enum Msg {
    Work(Job),
    Stop,
}

/// Which worker a request is routed to within one robot's route group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Route {
    /// Single-step RBD function batches (RNEA / FD / M⁻¹).
    Step(ArtifactFn),
    /// Trajectory rollouts through the workspace integrator.
    Traj,
}

/// Which datapath a trajectory route integrates q̈ with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrajLane {
    /// f64 workspace FD (ABA-composed) — the default.
    F64,
    /// Rounded fixed-point FD at this format (`QuantEngine`).
    Quant(QFormat),
    /// True-integer deferred FD at this format (`QIntEngine`) —
    /// rollouts on integer backends step through the qint path, not the
    /// rounded lane.
    Int(QFormat),
}

/// How one route executes its batches. Every variant names the default
/// [`QosClass`] of jobs submitted to the route without a per-request
/// override (`SubmitOptions::class`).
pub enum BackendSpec {
    /// Native f64 workspace engine: no artifacts, no external toolchain.
    Native {
        /// Robot served by this route.
        robot: Robot,
        /// RBD function this route evaluates.
        function: ArtifactFn,
        /// Batch size (requests coalesced per execution).
        batch: usize,
        /// Max chunks each assembled batch splits into on the global
        /// worker pool (`0` = one per pool worker, `1` = serial).
        /// Pooled execution is bitwise identical to serial.
        parallel: usize,
        /// Default QoS class for this route's jobs.
        class: QosClass,
    },
    /// Quantized fixed-point engine (`quant::qrbd` kernels) at a
    /// per-robot format — precision as a serving knob.
    NativeQuant {
        /// Robot served by this route.
        robot: Robot,
        /// RBD function this route evaluates.
        function: ArtifactFn,
        /// Batch size (requests coalesced per execution).
        batch: usize,
        /// Fixed-point format every evaluation is rounded to.
        fmt: QFormat,
        /// Max chunks each assembled batch splits into on the global
        /// worker pool (`0` = one per pool worker, `1` = serial) —
        /// quantized routes fan out like native ones, bitwise identical
        /// to serial.
        parallel: usize,
        /// Opt-in M⁻¹ error compensation (fitted at route startup,
        /// applied on the M⁻¹ route; other functions ignore it).
        comp: bool,
        /// Default QoS class for this route's jobs.
        class: QosClass,
    },
    /// True-integer `i64` engine (`quant::qint` kernels; FD/M⁻¹ on the
    /// division-deferring sweeps under a proved shift schedule). The
    /// engine is built at route startup from the scaling analysis — a
    /// rejected (robot, format) pair fails every request with the
    /// overflow witness instead of degrading to the rounded lane;
    /// registries validate at registration so served routes never hit
    /// that path.
    NativeInt {
        /// Robot served by this route.
        robot: Robot,
        /// RBD function this route evaluates.
        function: ArtifactFn,
        /// Batch size (requests coalesced per execution).
        batch: usize,
        /// Fixed-point format the integer lane carries.
        fmt: QFormat,
        /// Max chunks each assembled batch splits into on the global
        /// worker pool (`0` = one per pool worker, `1` = serial) —
        /// pooled execution is bitwise identical to serial.
        parallel: usize,
        /// Default QoS class for this route's jobs.
        class: QosClass,
    },
    /// Trajectory-rollout route: FD + semi-implicit Euler unrolled
    /// server-side on the robot's serving lane.
    Trajectory {
        /// Robot served by this route.
        robot: Robot,
        /// Rollouts coalesced per drain.
        batch: usize,
        /// Which datapath computes q̈ each step.
        lane: TrajLane,
        /// Default QoS class for this route's jobs.
        class: QosClass,
    },
    /// Fault-injection route for robustness tests and the loadgen
    /// harness: the native f64 engine wrapped in [`ChaosEngine`]. An
    /// infinite value in the first operand triggers an engine panic
    /// (exercising the batch-boundary catch and circuit breaker); a
    /// nonzero `delay_us` throttles every execution, pinning the
    /// route's capacity at ~`batch / delay_us` tasks/µs so overload
    /// scenarios are deterministic.
    Chaos {
        /// Robot served by this route.
        robot: Robot,
        /// RBD function this route evaluates.
        function: ArtifactFn,
        /// Batch size (requests coalesced per execution).
        batch: usize,
        /// Artificial per-execution delay [µs] (`0` = none).
        delay_us: u64,
        /// Default QoS class for this route's jobs.
        class: QosClass,
    },
    /// Compiled PJRT artifact (requires the `pjrt` feature + artifacts).
    #[cfg(feature = "pjrt")]
    Pjrt {
        /// The compiled artifact this route loads.
        meta: ArtifactMeta,
        /// Default QoS class for this route's jobs.
        class: QosClass,
    },
}

impl BackendSpec {
    /// Name of the robot this spec serves (the routing key).
    pub fn robot_name(&self) -> &str {
        match self {
            BackendSpec::Native { robot, .. }
            | BackendSpec::NativeQuant { robot, .. }
            | BackendSpec::NativeInt { robot, .. }
            | BackendSpec::Trajectory { robot, .. }
            | BackendSpec::Chaos { robot, .. } => &robot.name,
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { meta, .. } => &meta.robot,
        }
    }

    /// The route this spec backs.
    pub fn route(&self) -> Route {
        match self {
            BackendSpec::Native { function, .. }
            | BackendSpec::NativeQuant { function, .. }
            | BackendSpec::NativeInt { function, .. }
            | BackendSpec::Chaos { function, .. } => Route::Step(*function),
            BackendSpec::Trajectory { .. } => Route::Traj,
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { meta, .. } => Route::Step(meta.function),
        }
    }

    /// Default QoS class of jobs on this route.
    pub fn class(&self) -> QosClass {
        match self {
            BackendSpec::Native { class, .. }
            | BackendSpec::NativeQuant { class, .. }
            | BackendSpec::NativeInt { class, .. }
            | BackendSpec::Trajectory { class, .. }
            | BackendSpec::Chaos { class, .. } => *class,
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { class, .. } => *class,
        }
    }

    /// Batch size of this route (the retry-hint quantum of its gate).
    fn batch_size(&self) -> usize {
        match self {
            BackendSpec::Native { batch, .. }
            | BackendSpec::NativeQuant { batch, .. }
            | BackendSpec::NativeInt { batch, .. }
            | BackendSpec::Trajectory { batch, .. }
            | BackendSpec::Chaos { batch, .. } => *batch,
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { meta, .. } => meta.batch,
        }
    }
}

/// Uniform executor interface the step-batching loop drives.
trait BatchExecutor {
    fn batch(&self) -> usize;
    fn arity(&self) -> usize;
    fn n(&self) -> usize;
    fn out_per_task(&self) -> usize;
    /// Whether the executor's shapes are compiled-in (PJRT) and partial
    /// batches must be padded to `batch()`. The native engines accept
    /// any row count ≤ batch, so partial batches cost only the real
    /// tasks.
    fn pad_to_batch(&self) -> bool;
    /// Cumulative kinematics-memo `(hits, misses)` of the underlying
    /// engine. Routes without a memo (PJRT, non-`dyn_all` functions)
    /// report `(0, 0)` forever; `flush_step` records the per-execute
    /// delta into the serving stats.
    fn memo_counters(&self) -> (u64, u64) {
        (0, 0)
    }
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String>;
}

/// Adapter from the runtime [`DynamicsEngine`] trait (native f64 or
/// quantized) to the batching loop.
struct EngineExecutor(Box<dyn DynamicsEngine>);

impl BatchExecutor for EngineExecutor {
    fn batch(&self) -> usize {
        self.0.batch()
    }
    fn arity(&self) -> usize {
        self.0.function().arity()
    }
    fn n(&self) -> usize {
        self.0.n()
    }
    fn out_per_task(&self) -> usize {
        self.0.out_per_task()
    }
    fn pad_to_batch(&self) -> bool {
        false
    }
    fn memo_counters(&self) -> (u64, u64) {
        self.0.memo_counters()
    }
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String> {
        self.0.run(inputs).map_err(|e| e.0)
    }
}

/// PJRT client + engine pair; the engine is declared first so it drops
/// before the client that compiled it.
#[cfg(feature = "pjrt")]
struct PjrtExecutor {
    engine: crate::runtime::engine::Engine,
    _client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl BatchExecutor for PjrtExecutor {
    fn batch(&self) -> usize {
        self.engine.meta.batch
    }
    fn arity(&self) -> usize {
        self.engine.meta.function.arity()
    }
    fn n(&self) -> usize {
        self.engine.n
    }
    fn out_per_task(&self) -> usize {
        self.engine.expected_output_len() / self.engine.meta.batch
    }
    fn pad_to_batch(&self) -> bool {
        true
    }
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String> {
        self.engine.run(inputs).map_err(|e| e.0)
    }
}

/// One route's front-end state: the worker channel plus the shared
/// admission gate the dispatching side checks before enqueueing.
struct RouteHandle {
    tx: Sender<Msg>,
    gate: Arc<RouteGate>,
}

/// Routing front-end: `submit_to(robot, fn, …)` → per-(robot, function)
/// worker; `submit_traj(robot, …)` → the robot's trajectory worker.
pub struct Coordinator {
    routes: BTreeMap<(String, Route), RouteHandle>,
    default_robot: Option<String>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
    obs: Arc<ObsHub>,
}

impl Coordinator {
    /// Start one worker per backend spec under the default [`QosPolicy`].
    /// `n` is the robot DOF (used by the PJRT path to define operand
    /// shapes); `window_us` is the batching window (deadline to fill a
    /// batch). The first spec's robot becomes the default target of
    /// [`Coordinator::submit`].
    pub fn start(specs: Vec<BackendSpec>, n: usize, window_us: u64) -> Coordinator {
        Coordinator::start_with_policy(specs, n, window_us, QosPolicy::default())
    }

    /// [`Coordinator::start`] with an explicit overload policy
    /// (admission caps and circuit-breaker tuning, shared by every
    /// route).
    pub fn start_with_policy(
        specs: Vec<BackendSpec>,
        n: usize,
        window_us: u64,
        policy: QosPolicy,
    ) -> Coordinator {
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let obs = Arc::new(ObsHub::new());
        let class_names: Vec<&str> = QosClass::ALL.iter().map(|c| c.name()).collect();
        let default_robot = specs.first().map(|s| s.robot_name().to_string());
        let mut routes = BTreeMap::new();
        let mut workers = Vec::new();
        for spec in specs {
            let (tx, rx) = channel::<Msg>();
            let gate =
                Arc::new(RouteGate::new(spec.class(), policy, spec.batch_size(), window_us));
            routes.insert(
                (spec.robot_name().to_string(), spec.route()),
                RouteHandle { tx, gate: Arc::clone(&gate) },
            );
            let stages =
                obs.route_stages(spec.robot_name(), route_label(spec.route()), &class_names);
            let st = Arc::clone(&stats);
            workers.push(std::thread::spawn(move || {
                worker_loop(spec, n, window_us, rx, st, gate, stages)
            }));
        }
        Coordinator { routes, default_robot, workers, stats, obs }
    }

    /// The observability hub: always-on metrics registry plus the
    /// opt-in trace sink ([`ObsHub::enable_tracing`]).
    pub fn obs(&self) -> &Arc<ObsHub> {
        &self.obs
    }

    /// Start a native coordinator serving `functions` for one robot, one
    /// worker (and one workspace) per function, plus a trajectory route.
    /// Routes execute serially; pass `BackendSpec::Native { parallel, .. }`
    /// specs to [`Coordinator::start`] (or use
    /// [`RobotRegistry::register_parallel`]) for intra-route parallelism.
    pub fn start_native(
        robot: &Robot,
        functions: &[(ArtifactFn, usize)],
        window_us: u64,
    ) -> Coordinator {
        let n = robot.dof();
        let traj_batch = functions.iter().map(|&(_, b)| b).max().unwrap_or(8);
        let mut specs: Vec<BackendSpec> = functions
            .iter()
            .map(|&(function, batch)| BackendSpec::Native {
                robot: robot.clone(),
                function,
                batch,
                parallel: 1,
                class: QosClass::default(),
            })
            .collect();
        specs.push(BackendSpec::Trajectory {
            robot: robot.clone(),
            batch: traj_batch,
            lane: TrajLane::F64,
            class: QosClass::default(),
        });
        Coordinator::start(specs, n, window_us)
    }

    /// Start a coordinator over a [`RobotRegistry`]: for every registered
    /// robot, one worker per RBD function on the robot's chosen backend
    /// plus one trajectory route.
    pub fn start_registry(registry: &RobotRegistry, window_us: u64) -> Coordinator {
        Coordinator::start(registry.specs(), 0, window_us)
    }

    /// Start a PJRT coordinator over compiled artifacts.
    #[cfg(feature = "pjrt")]
    pub fn start_pjrt(artifacts: Vec<ArtifactMeta>, n: usize, window_us: u64) -> Coordinator {
        let specs = artifacts
            .into_iter()
            .map(|meta| BackendSpec::Pjrt { meta, class: QosClass::default() })
            .collect();
        Coordinator::start(specs, n, window_us)
    }

    /// Submit one step task to the **default** robot (the first spec
    /// passed to [`Coordinator::start`]); returns the channel the result
    /// arrives on. Single-robot deployments can ignore routing entirely.
    pub fn submit(&self, function: ArtifactFn, operands: Vec<Vec<f32>>) -> Receiver<JobResult> {
        self.submit_opts(function, operands, SubmitOptions::default())
    }

    /// [`Coordinator::submit`] with explicit QoS options (class override
    /// and/or deadline).
    pub fn submit_opts(
        &self,
        function: ArtifactFn,
        operands: Vec<Vec<f32>>,
        opts: SubmitOptions,
    ) -> Receiver<JobResult> {
        match self.default_robot.clone() {
            Some(name) => self.submit_to_opts(&name, function, operands, opts),
            None => {
                let (tx, rx) = channel();
                let _ = tx.send(Err(ServeError::BadRequest(format!(
                    "no executable for {}",
                    function.name()
                ))));
                rx
            }
        }
    }

    /// Submit one step task for a named robot.
    pub fn submit_to(
        &self,
        robot: &str,
        function: ArtifactFn,
        operands: Vec<Vec<f32>>,
    ) -> Receiver<JobResult> {
        self.submit_to_opts(robot, function, operands, SubmitOptions::default())
    }

    /// [`Coordinator::submit_to`] with explicit QoS options (class
    /// override and/or deadline).
    pub fn submit_to_opts(
        &self,
        robot: &str,
        function: ArtifactFn,
        operands: Vec<Vec<f32>>,
        opts: SubmitOptions,
    ) -> Receiver<JobResult> {
        self.dispatch(robot, Route::Step(function), JobPayload::Step(operands), opts)
    }

    /// Submit one trajectory rollout for a named robot. The response is
    /// flat f32 of length `2·H·N`: H q-rows then H q̇-rows (see
    /// [`NativeEngine::rollout`]).
    pub fn submit_traj(&self, robot: &str, req: TrajRequest) -> Receiver<JobResult> {
        self.submit_traj_opts(robot, req, SubmitOptions::default())
    }

    /// [`Coordinator::submit_traj`] with explicit QoS options.
    pub fn submit_traj_opts(
        &self,
        robot: &str,
        req: TrajRequest,
        opts: SubmitOptions,
    ) -> Receiver<JobResult> {
        self.dispatch(robot, Route::Traj, JobPayload::Traj(req), opts)
    }

    fn dispatch(
        &self,
        robot: &str,
        route: Route,
        payload: JobPayload,
        opts: SubmitOptions,
    ) -> Receiver<JobResult> {
        let (sink, rx) = ChannelSink::new();
        self.dispatch_sink(robot, route, payload, opts, Box::new(sink));
        rx
    }

    /// Submit one step task whose output goes to a caller-provided
    /// [`ResponseSink`] — the entry point the network layer uses (its
    /// sink writes `chunk` frames straight to the client socket).
    /// Admission, QoS classes, deadlines, and breakers apply exactly as
    /// on the channel API.
    pub fn submit_to_sink(
        &self,
        robot: &str,
        function: ArtifactFn,
        operands: Vec<Vec<f32>>,
        opts: SubmitOptions,
        sink: Box<dyn ResponseSink>,
    ) {
        self.dispatch_sink(robot, Route::Step(function), JobPayload::Step(operands), opts, sink);
    }

    /// Submit one trajectory rollout whose rows stream into a
    /// caller-provided [`ResponseSink`] as the integrator produces them.
    pub fn submit_traj_sink(
        &self,
        robot: &str,
        req: TrajRequest,
        opts: SubmitOptions,
        sink: Box<dyn ResponseSink>,
    ) {
        self.dispatch_sink(robot, Route::Traj, JobPayload::Traj(req), opts, sink);
    }

    fn dispatch_sink(
        &self,
        robot: &str,
        route: Route,
        payload: JobPayload,
        opts: SubmitOptions,
        mut sink: Box<dyn ResponseSink>,
    ) {
        match self.routes.get(&(robot.to_string(), route)) {
            Some(handle) => {
                let class = opts.class.unwrap_or(handle.gate.default_class);
                let mut span = self.obs.begin_span(robot, route_label(route), class.name());
                match handle.gate.admit(class) {
                    Ok(()) => {
                        // Ack before the worker can see the job, so the
                        // wire ordering `ack` < first `chunk` holds by
                        // construction.
                        sink.accepted();
                        span.stamp_enqueue();
                        let job = Job {
                            payload,
                            class,
                            deadline_us: opts.deadline_us,
                            enqueued: Instant::now(),
                            sink,
                            span,
                        };
                        // If the worker is gone the send fails; recover
                        // the job from the send error so its sink still
                        // gets a terminal answer, and give the admission
                        // unit back either way.
                        if let Err(send_err) = handle.tx.send(Msg::Work(job)) {
                            handle.gate.release(class);
                            if let Msg::Work(job) = send_err.0 {
                                job.fail(ServeError::ShuttingDown);
                            }
                        }
                    }
                    Err(err) => {
                        // Refused at admission: count it and answer
                        // immediately — the job was never enqueued. The
                        // short span still records the refusal terminal.
                        {
                            let mut st = lock_stats(&self.stats);
                            match &err {
                                ServeError::Rejected { .. } => st.rejected += 1,
                                ServeError::Shed { .. } => st.shed += 1,
                                _ => {}
                            }
                        }
                        span.finish(terminal_for(&err));
                        sink.done(Err(err));
                    }
                }
            }
            None => {
                let what = match route {
                    Route::Step(f) => format!("no route for robot '{robot}' / {}", f.name()),
                    Route::Traj => format!("no trajectory route for robot '{robot}'"),
                };
                sink.done(Err(ServeError::BadRequest(what)));
            }
        }
    }

    /// Names of the robots this coordinator routes for (sorted, deduped).
    pub fn robots(&self) -> Vec<String> {
        let mut names: Vec<String> = self.routes.keys().map(|(r, _)| r.clone()).collect();
        names.dedup();
        names
    }

    /// Snapshot of the aggregate serving statistics. Degrades (never
    /// panics) if a recorder previously poisoned the stats lock.
    pub fn stats(&self) -> ServeStats {
        lock_stats(&self.stats).snapshot()
    }

    /// Admitted-but-unanswered depth of one (robot, function, class)
    /// lane — `0` for unknown routes.
    pub fn depth(&self, robot: &str, function: ArtifactFn, class: QosClass) -> usize {
        self.routes
            .get(&(robot.to_string(), Route::Step(function)))
            .map_or(0, |h| h.gate.depth(class))
    }

    /// Stop every worker and join the threads. Jobs still queued are
    /// answered with [`ServeError::ShuttingDown`] — shutdown never hangs
    /// behind a backlog and never leaves a receiver waiting forever.
    pub fn shutdown(self) {
        for handle in self.routes.values() {
            let _ = handle.tx.send(Msg::Stop);
        }
        drop(self.routes);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Strict-priority class lanes of one route worker: `Control` drains
/// before `Interactive` before `Bulk`.
#[derive(Default)]
struct ClassLanes([VecDeque<Job>; 3]);

impl ClassLanes {
    fn push(&mut self, job: Job) {
        self.0[job.class.index()].push_back(job);
    }

    fn len(&self) -> usize {
        self.0.iter().map(VecDeque::len).sum()
    }

    fn is_empty(&self) -> bool {
        self.0.iter().all(VecDeque::is_empty)
    }

    /// Pick up to `cap` jobs in strict priority order, dropping expired
    /// jobs on the way (answered as [`ServeError::Expired`] — they are
    /// never executed) and jobs whose consumer is already gone
    /// (answered as [`ServeError::Cancelled`] — executing them would
    /// burn engine time on output nobody can receive).
    fn form_batch(
        &mut self,
        cap: usize,
        stats: &Arc<Mutex<StatsInner>>,
        gate: &RouteGate,
    ) -> Vec<Job> {
        let mut picked = Vec::with_capacity(cap);
        for lane in self.0.iter_mut() {
            while picked.len() < cap {
                let Some(job) = lane.pop_front() else { break };
                if !job.sink.alive() {
                    lock_stats(stats).cancelled += 1;
                    gate.release(job.class);
                    job.fail(ServeError::Cancelled);
                } else if let Some(waited_us) = job.expired() {
                    lock_stats(stats).expired += 1;
                    gate.release(job.class);
                    let deadline_us = job.deadline_us.unwrap_or(0);
                    job.fail(ServeError::Expired { deadline_us, waited_us });
                } else {
                    picked.push(job);
                }
            }
            if picked.len() >= cap {
                break;
            }
        }
        picked
    }

    /// Answer every queued job with [`ServeError::ShuttingDown`].
    fn fail_all_queued(&mut self, gate: &RouteGate) {
        for lane in self.0.iter_mut() {
            for job in lane.drain(..) {
                gate.release(job.class);
                job.fail(ServeError::ShuttingDown);
            }
        }
    }
}

/// What ended a drain pass.
enum Drained {
    /// Window expired or the batch filled: flush and keep serving.
    Open,
    /// `Stop` received: fail queued jobs and exit.
    Stopped,
    /// Every sender is gone (coordinator dropped without `shutdown`):
    /// flush what's queued, then exit.
    Disconnected,
}

/// Worker: owns its executor. PJRT handles are not `Send`, and the native
/// engines' workspaces are deliberately thread-local, so everything is
/// created inside the thread.
fn worker_loop(
    spec: BackendSpec,
    n: usize,
    window_us: u64,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<StatsInner>>,
    gate: Arc<RouteGate>,
    stages: RouteStages,
) {
    let _ = n; // used only by the pjrt arm
    let window = Duration::from_micros(window_us);
    match spec {
        BackendSpec::Native { robot, function, batch, parallel, class: _ } => {
            let exec = EngineExecutor(Box::new(NativeEngine::with_parallelism(
                robot, function, batch, parallel,
            )));
            step_worker(Box::new(exec), window, rx, stats, gate, stages);
        }
        BackendSpec::NativeQuant { robot, function, batch, fmt, parallel, comp, class: _ } => {
            let exec = EngineExecutor(Box::new(QuantEngine::with_options(
                robot, function, batch, fmt, parallel, comp,
            )));
            step_worker(Box::new(exec), window, rx, stats, gate, stages);
        }
        BackendSpec::NativeInt { robot, function, batch, fmt, parallel, class: _ } => {
            // The engine runs the scaling analysis; a rejected pair
            // fails every request with the witness — the route never
            // falls back to the rounded-f64 lane.
            match QIntEngine::with_parallelism(robot, function, batch, fmt, parallel) {
                Ok(engine) => step_worker(
                    Box::new(EngineExecutor(Box::new(engine))),
                    window,
                    rx,
                    stats,
                    gate,
                    stages,
                ),
                Err(e) => fail_all(&rx, &gate, &ServeError::Engine(e.0)),
            }
        }
        BackendSpec::Chaos { robot, function, batch, delay_us, class: _ } => {
            let exec =
                EngineExecutor(Box::new(ChaosEngine::new(robot, function, batch, delay_us)));
            step_worker(Box::new(exec), window, rx, stats, gate, stages);
        }
        BackendSpec::Trajectory { robot, batch, lane, class: _ } => {
            let engine: Box<dyn DynamicsEngine> = match lane {
                TrajLane::Quant(f) => Box::new(QuantEngine::new(robot, ArtifactFn::Fd, batch, f)),
                TrajLane::Int(f) => match QIntEngine::new(robot, ArtifactFn::Fd, batch, f) {
                    Ok(engine) => Box::new(engine),
                    Err(e) => {
                        fail_all(&rx, &gate, &ServeError::Engine(e.0));
                        return;
                    }
                },
                TrajLane::F64 => Box::new(NativeEngine::new(robot, ArtifactFn::Fd, batch)),
            };
            traj_worker(engine, batch, window, rx, stats, gate, stages);
        }
        #[cfg(feature = "pjrt")]
        BackendSpec::Pjrt { meta, class: _ } => {
            let client = match xla::PjRtClient::cpu() {
                Ok(c) => c,
                Err(e) => {
                    fail_all(&rx, &gate, &ServeError::Engine(format!("pjrt client: {e:?}")));
                    return;
                }
            };
            let engine = match crate::runtime::engine::Engine::load(&client, meta, n) {
                Ok(e) => e,
                Err(e) => {
                    fail_all(&rx, &gate, &ServeError::Engine(e.0));
                    return;
                }
            };
            step_worker(
                Box::new(PjrtExecutor { engine, _client: client }),
                window,
                rx,
                stats,
                gate,
                stages,
            );
        }
    }
}

/// Step-batch loop: block for the first job, drain within the window,
/// form one strict-priority batch (dropping expired jobs), execute it.
fn step_worker(
    mut exec: Box<dyn BatchExecutor>,
    window: Duration,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<StatsInner>>,
    gate: Arc<RouteGate>,
    stages: RouteStages,
) {
    let b = exec.batch().max(1);
    let mut lanes = ClassLanes::default();
    loop {
        if lanes.is_empty() {
            match rx.recv() {
                Ok(Msg::Work(j)) => lanes.push(j),
                Ok(Msg::Stop) | Err(_) => return,
            }
        }
        match drain_into(&rx, &mut lanes, b, window) {
            Drained::Open => {
                let picked = lanes.form_batch(b, &stats, &gate);
                flush_step(exec.as_mut(), picked, &stats, &gate, &stages);
            }
            Drained::Stopped => {
                lanes.fail_all_queued(&gate);
                return;
            }
            Drained::Disconnected => {
                while !lanes.is_empty() {
                    let picked = lanes.form_batch(b, &stats, &gate);
                    flush_step(exec.as_mut(), picked, &stats, &gate, &stages);
                }
                return;
            }
        }
    }
}

/// Trajectory loop: same skeleton as [`step_worker`], executing rollouts
/// back-to-back on one engine (one workspace, zero per-step dispatch).
fn traj_worker(
    mut engine: Box<dyn DynamicsEngine>,
    cap: usize,
    window: Duration,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<StatsInner>>,
    gate: Arc<RouteGate>,
    stages: RouteStages,
) {
    let cap = cap.max(1);
    let mut lanes = ClassLanes::default();
    loop {
        if lanes.is_empty() {
            match rx.recv() {
                Ok(Msg::Work(j)) => lanes.push(j),
                Ok(Msg::Stop) | Err(_) => return,
            }
        }
        match drain_into(&rx, &mut lanes, cap, window) {
            Drained::Open => {
                let picked = lanes.form_batch(cap, &stats, &gate);
                flush_traj(engine.as_mut(), picked, &stats, &gate, cap, &stages);
            }
            Drained::Stopped => {
                lanes.fail_all_queued(&gate);
                return;
            }
            Drained::Disconnected => {
                while !lanes.is_empty() {
                    let picked = lanes.form_batch(cap, &stats, &gate);
                    flush_traj(engine.as_mut(), picked, &stats, &gate, cap, &stages);
                }
                return;
            }
        }
    }
}

/// Collect further work until `cap` jobs are queued or the window
/// expires.
fn drain_into(
    rx: &Receiver<Msg>,
    lanes: &mut ClassLanes,
    cap: usize,
    window: Duration,
) -> Drained {
    let deadline = Instant::now() + window;
    while lanes.len() < cap {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(Msg::Work(j)) => lanes.push(j),
            Ok(Msg::Stop) => return Drained::Stopped,
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => return Drained::Disconnected,
        }
    }
    Drained::Open
}

/// Execute one formed step batch and fan results out. The engine call is
/// wrapped in `catch_unwind`: a panicking evaluation fails only this
/// batch (answered as [`ServeError::Engine`]) and counts toward the
/// route's circuit breaker instead of killing the worker thread.
fn flush_step(
    exec: &mut dyn BatchExecutor,
    mut picked: Vec<Job>,
    stats: &Arc<Mutex<StatsInner>>,
    gate: &RouteGate,
    stages: &RouteStages,
) {
    if picked.is_empty() {
        return;
    }
    let b = exec.batch();
    let n = exec.n();
    let arity = exec.arity();

    // Batch formation: the queue stage of every picked job ends here.
    let t_formed = Instant::now();
    for job in picked.iter_mut() {
        job.span.stamp_formed();
        stages.record_queue(
            job.class.index(),
            t_formed.saturating_duration_since(job.enqueued).as_micros() as u64,
        );
    }

    // Reject malformed jobs up front: a bad task must fail alone instead
    // of poisoning (or panicking) the whole assembled batch.
    let mut valid = Vec::with_capacity(picked.len());
    for job in picked {
        let ok = match &job.payload {
            JobPayload::Step(ops) => ops.len() == arity && ops.iter().all(|op| op.len() == n),
            JobPayload::Traj(_) => false,
        };
        if ok {
            valid.push(job);
        } else {
            gate.release(job.class);
            job.fail(ServeError::BadRequest(format!(
                "bad operands: expected {arity} arrays of length {n}"
            )));
        }
    }
    let mut picked = valid;
    if picked.is_empty() {
        return;
    }
    // `form_batch` already capped the pick at the batch size.
    let fill = picked.len();

    // Assemble operands, padding the tail by repeating the last task
    // (keeps the padded rows numerically benign).
    let mut inputs: Vec<Vec<f32>> = vec![Vec::with_capacity(b * n); arity];
    for job in picked.iter() {
        if let JobPayload::Step(ops) = &job.payload {
            for (k, op) in ops.iter().enumerate().take(arity) {
                inputs[k].extend_from_slice(op);
            }
        }
    }
    if exec.pad_to_batch() {
        for _ in fill..b {
            for input in inputs.iter_mut() {
                let last: Vec<f32> = input[(fill - 1) * n..fill * n].to_vec();
                input.extend_from_slice(&last);
            }
        }
    }

    let (hits_before, misses_before) = exec.memo_counters();
    for job in picked.iter_mut() {
        job.span.stamp_kernel_start();
    }
    let t0 = Instant::now();
    let result = catch_unwind(AssertUnwindSafe(|| exec.execute(&inputs)))
        .unwrap_or_else(|p| Err(format!("engine panicked: {}", panic_message(p.as_ref()))));
    let exec_us = t0.elapsed().as_micros() as f64;
    for job in picked.iter_mut() {
        job.span.stamp_kernel_end();
        stages.record_kernel(job.class.index(), exec_us as u64);
    }
    // Memo activity is recorded as a per-execute delta so the serving
    // stats aggregate correctly across many routes sharing one stats
    // block. Non-memo routes report (0, 0) forever — zero delta.
    let (hits_after, misses_after) = exec.memo_counters();
    {
        let mut st = lock_stats(stats);
        st.memo_hits += hits_after.saturating_sub(hits_before);
        st.memo_misses += misses_after.saturating_sub(misses_before);
    }

    let out_per_task = exec.out_per_task();
    match result {
        Ok(flat) => {
            gate.on_success();
            let mut st = lock_stats(stats);
            for job in picked.iter() {
                st.record(job.class, job.enqueued.elapsed().as_micros() as f64);
            }
            drop(st);
            for (i, mut job) in picked.drain(..).enumerate() {
                gate.release(job.class);
                let t_eg = Instant::now();
                job.span.stamp_chunk();
                job.sink.chunk(&flat[i * out_per_task..(i + 1) * out_per_task]);
                job.sink.done(Ok(()));
                stages.record_egress(job.class.index(), t_eg.elapsed().as_micros() as u64);
                job.span.finish(Terminal::Done);
            }
        }
        Err(msg) => {
            if gate.on_failure() {
                lock_stats(stats).breaker_trips += 1;
            }
            for job in picked.drain(..) {
                gate.release(job.class);
                job.fail(ServeError::Engine(msg.clone()));
            }
        }
    }
    // Record the batch on BOTH paths: a failed execution still consumed
    // a batch slot and wall clock, and skipping it skewed `mean_fill` /
    // `mean_exec_us` against `batches` under error bursts.
    lock_stats(stats).record_batch(fill as f64 / b as f64, exec_us);
    stages.record_batch((fill * 100 / b.max(1)) as u64, exec_us as u64);
}

/// Execute one formed trajectory batch (rollouts back-to-back) and fan
/// results out. Each rollout is individually `catch_unwind`-wrapped so a
/// panicking integration fails only its own request.
///
/// Rollouts **stream**: every integrated row goes into the job's sink
/// via [`DynamicsEngine::rollout_stream`] before the next step runs, so
/// a network client sees its first `chunk` frame while the horizon is
/// still integrating. Between steps the sink's `alive()` is polled — a
/// consumer that disconnected mid-horizon cancels the remaining steps
/// (counted as a completed job: the work done so far was delivered as
/// far as the wire allowed).
fn flush_traj(
    engine: &mut dyn DynamicsEngine,
    mut picked: Vec<Job>,
    stats: &Arc<Mutex<StatsInner>>,
    gate: &RouteGate,
    cap: usize,
    stages: &RouteStages,
) {
    if picked.is_empty() {
        return;
    }
    // Batch formation: the queue stage of every picked rollout ends here.
    let t_formed = Instant::now();
    for job in picked.iter_mut() {
        job.span.stamp_formed();
        stages.record_queue(
            job.class.index(),
            t_formed.saturating_duration_since(job.enqueued).as_micros() as u64,
        );
    }
    let fill = picked.len().min(cap) as f64 / cap as f64;
    let t0 = Instant::now();
    for mut job in picked.drain(..) {
        job.span.stamp_kernel_start();
        let t_kernel = Instant::now();
        let result = match &job.payload {
            JobPayload::Traj(req) => {
                let n = engine.n();
                // Sizing hint for the sink; real traffic always divides
                // evenly (validate_rollout enforces it before step 1).
                let rows_hint = if n > 0 && req.tau.len() % n == 0 { req.tau.len() / n } else { 0 };
                job.sink.begin_stream(rows_hint, n);
                let sink = &mut job.sink;
                let span = &mut job.span;
                catch_unwind(AssertUnwindSafe(|| {
                    engine.rollout_stream(&req.q0, &req.qd0, &req.tau, req.dt, &mut |row| {
                        span.stamp_chunk();
                        sink.chunk(row);
                        sink.alive()
                    })
                }))
                .unwrap_or_else(|p| {
                    Err(crate::runtime::EngineError(format!(
                        "engine panicked: {}",
                        panic_message(p.as_ref())
                    )))
                })
                .map(|_emitted| ())
                .map_err(|e| ServeError::Engine(e.0))
            }
            JobPayload::Step(_) => {
                Err(ServeError::BadRequest("step operands sent to a trajectory route".into()))
            }
        };
        job.span.stamp_kernel_end();
        stages.record_kernel(job.class.index(), t_kernel.elapsed().as_micros() as u64);
        match &result {
            Ok(()) => {
                gate.on_success();
                lock_stats(stats).record(job.class, job.enqueued.elapsed().as_micros() as f64);
            }
            Err(ServeError::Engine(_)) => {
                if gate.on_failure() {
                    lock_stats(stats).breaker_trips += 1;
                }
            }
            Err(_) => {}
        }
        gate.release(job.class);
        let terminal = match &result {
            Ok(()) => Terminal::Done,
            Err(e) => terminal_for(e),
        };
        let t_eg = Instant::now();
        job.sink.done(result);
        stages.record_egress(job.class.index(), t_eg.elapsed().as_micros() as u64);
        job.span.finish(terminal);
    }
    let exec_us = t0.elapsed().as_micros() as f64;
    lock_stats(stats).record_batch(fill, exec_us);
    stages.record_batch((fill * 100.0) as u64, exec_us as u64);
}

/// Answer every queued (and future) request on this route with the same
/// error — the loud-failure path for routes whose engine refused to
/// build (rejected qint formats, missing PJRT artifacts).
fn fail_all(rx: &Receiver<Msg>, gate: &RouteGate, err: &ServeError) {
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Work(j) => {
                gate.release(j.class);
                j.fail(err.clone());
            }
            Msg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin_robot;

    #[test]
    fn submit_unknown_function_errors_fast() {
        let coord = Coordinator::start(Vec::new(), 7, 100);
        let rx = coord.submit(ArtifactFn::Minv, vec![vec![0.0; 7]]);
        let res = rx.recv().unwrap();
        assert!(matches!(res, Err(ServeError::BadRequest(_))));
        coord.shutdown();
    }

    #[test]
    fn native_worker_answers_without_artifacts() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 8)], 100);
        let rx = coord.submit(ArtifactFn::Rnea, vec![vec![0.1; n]; 3]);
        let res = rx.recv().expect("worker must answer");
        let out = res.expect("native execution succeeds");
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|x| x.is_finite()));
        coord.shutdown();
    }

    #[test]
    fn native_worker_reports_shape_errors() {
        let robot = builtin_robot("iiwa").unwrap();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 4)], 100);
        // Wrong arity: one operand instead of three.
        let rx = coord.submit(ArtifactFn::Rnea, vec![vec![0.0; 7]]);
        let res = rx.recv().expect("worker must answer even on failure");
        assert!(matches!(res, Err(ServeError::BadRequest(_))));
        coord.shutdown();
    }

    #[test]
    fn unknown_robot_errors_fast() {
        let robot = builtin_robot("iiwa").unwrap();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 4)], 100);
        let rx = coord.submit_to("panda", ArtifactFn::Rnea, vec![vec![0.0; 7]; 3]);
        assert!(rx.recv().unwrap().is_err());
        coord.shutdown();
    }

    #[test]
    fn trajectory_route_answers() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Fd, 8)], 100);
        let h = 5;
        let req = TrajRequest {
            q0: vec![0.1; n],
            qd0: vec![0.0; n],
            tau: vec![0.0; h * n],
            dt: 1e-3,
        };
        let rx = coord.submit_traj("iiwa", req);
        let out = rx.recv().expect("answer").expect("rollout ok");
        assert_eq!(out.len(), 2 * h * n);
        assert!(out.iter().all(|x| x.is_finite()));
        // Malformed rollouts fail alone.
        let bad =
            TrajRequest { q0: vec![0.0; n - 1], qd0: vec![0.0; n], tau: vec![0.0; n], dt: 1e-3 };
        let rx = coord.submit_traj("iiwa", bad);
        assert!(rx.recv().unwrap().is_err());
        coord.shutdown();
    }

    /// A zero admission cap rejects at submission — deterministically,
    /// regardless of worker speed — with a structured retry hint, and
    /// the rejection is counted.
    #[test]
    fn zero_cap_rejects_at_admission() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let spec = BackendSpec::Native {
            robot,
            function: ArtifactFn::Rnea,
            batch: 4,
            parallel: 1,
            class: QosClass::Bulk,
        };
        let policy = QosPolicy { queue_cap: [0, 0, 0], ..QosPolicy::default() };
        let coord = Coordinator::start_with_policy(vec![spec], n, 100, policy);
        let res = coord.submit(ArtifactFn::Rnea, vec![vec![0.1; n]; 3]).recv().unwrap();
        match res {
            Err(ServeError::Rejected { class, retry_after_us, .. }) => {
                assert_eq!(class, QosClass::Bulk, "route default class applies");
                assert!(retry_after_us >= 100, "hint covers at least one window");
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert_eq!(coord.stats().rejected, 1);
        coord.shutdown();
    }

    /// A `dyn_all` route answers the fused flat layout
    /// (q̈ ‖ M⁻¹ ‖ C, length n²+2n) and a warm repeat of a bitwise
    /// identical request is served out of the kinematics memo —
    /// visible in the aggregate serving stats.
    #[test]
    fn dyn_all_route_serves_fused_layout_and_counts_memo_hits() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let spec = BackendSpec::Native {
            robot,
            function: ArtifactFn::DynAll,
            batch: 4,
            parallel: 1,
            class: QosClass::default(),
        };
        let coord = Coordinator::start(vec![spec], n, 100);
        let ops = vec![vec![0.1; n], vec![0.05; n], vec![0.2; n]];
        let cold = coord.submit(ArtifactFn::DynAll, ops.clone()).recv().unwrap().unwrap();
        assert_eq!(cold.len(), n * n + 2 * n, "q̈ | M⁻¹ | C flat layout");
        assert!(cold.iter().all(|x| x.is_finite()));
        let warm = coord.submit(ArtifactFn::DynAll, ops).recv().unwrap().unwrap();
        assert_eq!(warm, cold, "memo hit must be bitwise identical to the cold miss");
        let st = coord.stats();
        assert!(st.memo_hits >= 1, "warm repeat must hit the kinematics memo");
        assert_eq!(st.memo_hits + st.memo_misses, 2, "two tasks, each memo-accounted");
        coord.shutdown();
    }

    /// Trajectory responses stream through the per-job sink: every
    /// integrated row arrives as its own chunk (H chunks of `2·N`,
    /// matching the buffered layout bitwise), and a sink that reports
    /// dead after 3 rows cancels the remaining horizon — streaming is
    /// real, not a post-hoc split of a finished buffer.
    #[test]
    fn traj_sink_streams_rows_and_cancels_when_dead() {
        struct Collect {
            rows: Arc<Mutex<Vec<Vec<f32>>>>,
            done_tx: Sender<Result<(), ServeError>>,
            live_rows: usize,
        }
        impl ResponseSink for Collect {
            fn chunk(&mut self, data: &[f32]) {
                self.rows.lock().unwrap().push(data.to_vec());
            }
            fn done(&mut self, result: Result<(), ServeError>) {
                let _ = self.done_tx.send(result);
            }
            fn alive(&self) -> bool {
                self.rows.lock().unwrap().len() < self.live_rows
            }
        }
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Fd, 8)], 100);
        let h = 6;
        let req = TrajRequest {
            q0: vec![0.1; n],
            qd0: vec![0.0; n],
            tau: vec![0.0; h * n],
            dt: 1e-3,
        };
        let rows = Arc::new(Mutex::new(Vec::new()));
        let (done_tx, done_rx) = channel();
        coord.submit_traj_sink(
            "iiwa",
            req.clone(),
            SubmitOptions::default(),
            Box::new(Collect { rows: Arc::clone(&rows), done_tx, live_rows: usize::MAX }),
        );
        done_rx.recv().expect("terminal").expect("rollout ok");
        let got = rows.lock().unwrap().clone();
        assert_eq!(got.len(), h, "one chunk per integrated row");
        assert!(got.iter().all(|r| r.len() == 2 * n));
        let flat = coord.submit_traj("iiwa", req.clone()).recv().unwrap().unwrap();
        for (t, row) in got.iter().enumerate() {
            assert_eq!(&row[..n], &flat[t * n..(t + 1) * n], "q row {t}");
            assert_eq!(&row[n..], &flat[(h + t) * n..(h + t + 1) * n], "qd row {t}");
        }
        // A sink that dies after 3 rows cancels the rest of the horizon.
        let rows = Arc::new(Mutex::new(Vec::new()));
        let (done_tx, done_rx) = channel();
        coord.submit_traj_sink(
            "iiwa",
            req,
            SubmitOptions::default(),
            Box::new(Collect { rows: Arc::clone(&rows), done_tx, live_rows: 3 }),
        );
        done_rx.recv().expect("terminal").expect("cancelled rollout still completes");
        assert_eq!(rows.lock().unwrap().len(), 3, "no row emitted after the sink died");
        coord.shutdown();
    }

    /// A job whose deadline has already passed when the batch forms is
    /// answered `Expired` and never executed.
    #[test]
    fn expired_job_is_dropped_not_executed() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 8)], 100);
        let opts = SubmitOptions::deadline_us(0);
        let rx = coord.submit_opts(ArtifactFn::Rnea, vec![vec![0.1; n]; 3], opts);
        match rx.recv().unwrap() {
            Err(ServeError::Expired { deadline_us, .. }) => assert_eq!(deadline_us, 0),
            other => panic!("expected Expired, got {other:?}"),
        }
        let st = coord.stats();
        assert_eq!(st.expired, 1);
        assert_eq!(st.completed, 0, "an expired job must never execute");
        coord.shutdown();
    }

    /// A queued job whose sink is already dead when the batch forms is
    /// answered `Cancelled`, never executed, and its admission unit is
    /// released — a disconnected peer cannot leave a stuck batch slot.
    #[test]
    fn dead_sink_job_is_cancelled_not_executed() {
        struct DeadSink {
            done_tx: Sender<Result<(), ServeError>>,
        }
        impl ResponseSink for DeadSink {
            fn chunk(&mut self, _data: &[f32]) {}
            fn done(&mut self, result: Result<(), ServeError>) {
                let _ = self.done_tx.send(result);
            }
            fn alive(&self) -> bool {
                false
            }
        }
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 8)], 100);
        let (done_tx, done_rx) = channel();
        coord.submit_to_sink(
            "iiwa",
            ArtifactFn::Rnea,
            vec![vec![0.1; n]; 3],
            SubmitOptions::default(),
            Box::new(DeadSink { done_tx }),
        );
        match done_rx.recv().expect("terminal answer") {
            Err(ServeError::Cancelled) => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
        let st = coord.stats();
        assert_eq!(st.cancelled, 1);
        assert_eq!(st.completed, 0, "a cancelled job must never execute");
        assert_eq!(
            coord.depth("iiwa", ArtifactFn::Rnea, QosClass::Interactive),
            0,
            "cancellation must release the admission unit"
        );
        coord.shutdown();
    }
}
