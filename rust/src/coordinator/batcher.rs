//! Dynamic batcher: per-(function) worker threads that coalesce requests
//! into engine-sized batches under a latency window.

use super::stats::{ServeStats, StatsInner};
use crate::runtime::artifact::{ArtifactFn, ArtifactMeta};
use crate::runtime::engine::Engine;
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One request: flat f32 operands for a single task (each of length N,
/// or N·N where applicable).
pub struct Job {
    pub operands: Vec<Vec<f32>>,
    pub enqueued: Instant,
    pub resp: Sender<JobResult>,
}

/// Per-task result: the flat f32 output slice for this task.
pub type JobResult = Result<Vec<f32>, String>;

enum Msg {
    Work(Job),
    Stop,
}

/// Routing front-end: submit() → per-function worker.
pub struct Coordinator {
    routes: BTreeMap<ArtifactFn, Sender<Msg>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
}

impl Coordinator {
    /// Start one worker per artifact. `n` is the robot DOF; `window_us`
    /// is the batching window (deadline to fill a batch).
    pub fn start(artifacts: Vec<ArtifactMeta>, n: usize, window_us: u64) -> Coordinator {
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let mut routes = BTreeMap::new();
        let mut workers = Vec::new();
        for meta in artifacts {
            let (tx, rx) = channel::<Msg>();
            routes.insert(meta.function, tx);
            let st = Arc::clone(&stats);
            workers.push(std::thread::spawn(move || worker_loop(meta, n, window_us, rx, st)));
        }
        Coordinator { routes, workers, stats }
    }

    /// Submit one task; returns the channel the result arrives on.
    pub fn submit(&self, function: ArtifactFn, operands: Vec<Vec<f32>>) -> Receiver<JobResult> {
        let (tx, rx) = channel();
        match self.routes.get(&function) {
            Some(route) => {
                let job = Job { operands, enqueued: Instant::now(), resp: tx };
                if route.send(Msg::Work(job)).is_err() {
                    // Worker gone: report through the response channel by
                    // dropping tx — recv() errors out on the caller side.
                }
            }
            None => {
                let _ = tx.send(Err(format!("no executable for {}", function.name())));
            }
        }
        rx
    }

    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().snapshot()
    }

    pub fn shutdown(self) {
        for (_, tx) in &self.routes {
            let _ = tx.send(Msg::Stop);
        }
        drop(self.routes);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Worker: owns its own PJRT client + executable (PJRT handles are not
/// Send, so everything is created inside the thread).
fn worker_loop(
    meta: ArtifactMeta,
    n: usize,
    window_us: u64,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<StatsInner>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            fail_all(&rx, &format!("pjrt client: {e:?}"));
            return;
        }
    };
    let engine = match Engine::load(&client, meta, n) {
        Ok(e) => e,
        Err(e) => {
            fail_all(&rx, &e.0);
            return;
        }
    };
    let b = engine.meta.batch;
    let window = Duration::from_micros(window_us);

    let mut queue: Vec<Job> = Vec::with_capacity(b);
    loop {
        // Block for the first job, then drain within the window.
        match rx.recv() {
            Ok(Msg::Work(j)) => queue.push(j),
            Ok(Msg::Stop) | Err(_) => break,
        }
        let deadline = Instant::now() + window;
        while queue.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Work(j)) => queue.push(j),
                Ok(Msg::Stop) => {
                    flush(&engine, &mut queue, &stats);
                    return;
                }
                Err(_) => break,
            }
        }
        flush(&engine, &mut queue, &stats);
    }
    flush(&engine, &mut queue, &stats);
}

/// Execute the queued jobs as one padded batch and fan results out.
fn flush(engine: &Engine, queue: &mut Vec<Job>, stats: &Arc<Mutex<StatsInner>>) {
    if queue.is_empty() {
        return;
    }
    let b = engine.meta.batch;
    let n = engine.n;
    let arity = engine.meta.function.arity();
    let fill = queue.len().min(b);

    // Assemble operands, padding the tail by repeating the last task
    // (keeps the padded rows numerically benign).
    let mut inputs: Vec<Vec<f32>> = vec![Vec::with_capacity(b * n); arity];
    for job in queue.iter().take(fill) {
        for (k, op) in job.operands.iter().enumerate().take(arity) {
            inputs[k].extend_from_slice(op);
        }
    }
    for _ in fill..b {
        for k in 0..arity {
            let last: Vec<f32> = inputs[k][(fill - 1) * n..fill * n].to_vec();
            inputs[k].extend_from_slice(&last);
        }
    }

    let t0 = Instant::now();
    let result = engine.run(&inputs);
    let exec_us = t0.elapsed().as_micros() as f64;

    let out_per_task = engine.expected_output_len() / b;
    match result {
        Ok(flat) => {
            for (i, job) in queue.drain(..).enumerate() {
                if i < fill {
                    let chunk = flat[i * out_per_task..(i + 1) * out_per_task].to_vec();
                    let wait_us = job.enqueued.elapsed().as_micros() as f64;
                    stats.lock().unwrap().record(wait_us);
                    let _ = job.resp.send(Ok(chunk));
                } else {
                    let _ = job.resp.send(Err("overflow past batch".into()));
                }
            }
            stats.lock().unwrap().record_batch(fill as f64 / b as f64, exec_us);
        }
        Err(e) => {
            for job in queue.drain(..) {
                let _ = job.resp.send(Err(e.0.clone()));
            }
        }
    }
}

fn fail_all(rx: &Receiver<Msg>, err: &str) {
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Work(j) => {
                let _ = j.resp.send(Err(err.to_string()));
            }
            Msg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn submit_unknown_function_errors_fast() {
        let coord = Coordinator::start(Vec::new(), 7, 100);
        let rx = coord.submit(ArtifactFn::Minv, vec![vec![0.0; 7]]);
        let res = rx.recv().unwrap();
        assert!(res.is_err());
        coord.shutdown();
    }

    #[test]
    fn worker_with_bad_artifact_reports_error() {
        let meta = ArtifactMeta {
            robot: "iiwa".into(),
            function: ArtifactFn::Rnea,
            batch: 4,
            path: PathBuf::from("/nonexistent/iiwa_rnea_b4.hlo.txt"),
        };
        let coord = Coordinator::start(vec![meta], 7, 100);
        let rx = coord.submit(ArtifactFn::Rnea, vec![vec![0.0; 7]; 3]);
        let res = rx.recv().expect("worker must answer even on failure");
        assert!(res.is_err());
        coord.shutdown();
    }
}
