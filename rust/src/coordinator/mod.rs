//! Serving coordinator: request routing + dynamic batching — the L3
//! system layer. Mirrors the accelerator's operating model: the RTP
//! pipeline reaches peak throughput only when tasks are batched through
//! it, so the coordinator aggregates concurrent control requests into
//! fixed-size batches per (robot, function) route, pads partial batches,
//! and fans results back out.
//!
//! Multi-tenancy comes from the [`RobotRegistry`]: one coordinator owns
//! one engine + workspace pool per registered robot and routes jobs by
//! robot name, with a per-robot backend choice — the f64 native engine,
//! the quantized engine at a per-robot `QFormat`, or (behind the `pjrt`
//! feature) AOT-compiled HLO artifacts executed through PJRT. Trajectory
//! requests carry whole `(q₀, q̇₀, τ₀…τ_H)` rollouts and are unrolled
//! server-side through the workspace integrator, amortizing dispatch
//! over the horizon.
//!
//! Threading: PJRT client/executable handles are not `Send`, and the
//! native workspaces are deliberately thread-local, so each worker
//! thread owns its own executor; requests cross threads through
//! channels.
//!
//! Overload robustness lives in [`qos`]: per-route priority classes
//! (`Control > Interactive > Bulk`), bounded per-class admission with
//! structured `Rejected { retry_after_us }` shedding, deadline-aware
//! batch formation (expired jobs are dropped, never executed), and a
//! per-route circuit breaker behind a batch-boundary panic catch. The
//! [`loadgen`] module is the open-loop Poisson/ramp harness that
//! measures all of it (`draco loadgen`).

#![warn(missing_docs)]

pub mod batcher;
pub mod loadgen;
pub mod qos;
pub mod registry;
pub mod stats;

pub use batcher::{
    BackendSpec, ChannelSink, Coordinator, Job, JobPayload, JobResult, ResponseSink, Route,
    TrajLane, TrajRequest,
};
pub use qos::{QosClass, QosPolicy, ServeError, SubmitOptions};
pub use registry::{BackendKind, RobotEntry, RobotRegistry, DEFAULT_QUANT_FORMAT};
pub use stats::{ClassStats, ServeStats};

use crate::model::State;
use crate::quant::qint::quant_rnea_i64;
use crate::quant::qrbd::quant_rnea;
use crate::runtime::artifact::ArtifactFn;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use std::time::Instant;

/// `draco serve`: bring up the coordinator, push a synthetic workload
/// through it, verify numerics against the backend's reference
/// implementation, and report latency/throughput.
///
/// * `--robots iiwa,atlas:qint@12.14[,hyq:quant@14.18+comp,arm=path.urdf]`
///   — the registry spec: which robots this process serves and each
///   robot's backend (`native` default, `quant` = rounded fixed point,
///   `qint` = the true-integer lane — accepted only when the
///   fixed-point scaling analysis proves the format, rejected with the
///   overflow witness otherwise; `+comp` = fitted M⁻¹ error
///   compensation on the quantized M⁻¹ route; `name=path.urdf` loads a
///   robot through the URDF-lite importer; a trailing `!control` /
///   `!interactive` / `!bulk` sets the robot's QoS class — `Control`
///   drains first under overload, `Bulk` sheds first; see
///   [`RobotRegistry::from_cli_spec`]). `--robot NAME` remains as a
///   single-robot shorthand.
/// * `--backend native|pjrt` — `native` (default) serves the registry
///   from the workspace cores; `pjrt` needs artifacts + the feature and
///   is single-robot (`--robots`/`--traj` are native-only and warn if
///   passed).
/// * `--traj H` — additionally submit trajectory requests with an
///   H-step horizon through each robot's rollout route (native
///   backend).
/// * `--par P` — split each **step** route's assembled batches — native
///   and quantized alike; the worker pool is engine-generic — into up
///   to P chunks on the global worker pool (`0` = one per pool worker,
///   default 1 = serial; bitwise identical either way). Trajectory
///   rollouts stay serial (each step depends on the last).
/// * `--requests N`, `--batch B`, `--window-us W`, `--dt S` — workload
///   shape.
/// * `--listen ADDR` (native backend) — after the in-process workload
///   passes, bind the streaming JSONL front-end on `ADDR` (use
///   `127.0.0.1:0` for an ephemeral port) and run the wire self-drive
///   smoke against it over real TCP: step routes, chunked `dyn_all`
///   and trajectory streams (compared bitwise against the in-process
///   rollout), deadline expiry, and malformed-frame handling.
/// * `--tee PATH` — with `--listen`, record every inbound request line
///   and outbound frame to a JSONL log that `draco replay PATH` can
///   re-execute and verify bitwise.
/// * `--trace PATH` (native backend) — enable per-request span tracing
///   and export the run as Chrome trace-event JSON to `PATH` on exit
///   (open in `chrome://tracing` or Perfetto; validate with
///   `draco stats --trace-file PATH`). Tracing off costs one atomic
///   load per request — see the `trace_overhead` bench row.
pub fn serve_cli(args: &Args) -> i32 {
    let backend = args.opt_or("backend", "native").to_string();
    let requests = args.opt_usize("requests", 512);
    let window_us = args.opt_usize("window-us", 200);
    let batch = args.opt_usize("batch", 64);

    match backend.as_str() {
        "native" => {
            let spec = args
                .opt("robots")
                .map(str::to_string)
                .unwrap_or_else(|| args.opt_or("robot", "iiwa").to_string());
            let mut registry = match RobotRegistry::from_cli_spec(&spec, batch) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("bad --robots spec: {e}");
                    return 2;
                }
            };
            let par = args.opt_usize("par", 1);
            if par != 1 {
                registry.set_parallelism(par);
            }
            println!("serving {} robot(s), batch {batch}, window {window_us} µs:", registry.len());
            for name in registry.names() {
                let entry = registry.get(&name).expect("registered");
                println!(
                    "  {name}: {} DOF, backend {}{}, qos {}",
                    entry.robot.dof(),
                    entry.backend.label(),
                    if entry.comp { " +comp" } else { "" },
                    entry.qos
                );
            }
            let coord = Coordinator::start_registry(&registry, window_us as u64);
            if args.opt("trace").is_some() {
                coord.obs().enable_tracing(
                    crate::obs::TRACE_RINGS,
                    crate::obs::TRACE_RING_CAPACITY,
                );
                println!("tracing enabled ({} rings × {} spans)",
                    crate::obs::TRACE_RINGS, crate::obs::TRACE_RING_CAPACITY);
            }
            let traj = args.opt_usize("traj", 0);
            let dt = args.opt_f64("dt", 1e-3);
            let code = run_native_workload(&coord, &registry, requests, traj, dt);
            if code != 0 {
                export_trace(args, coord.obs());
                return code;
            }
            if let Some(listen) = args.opt("listen") {
                let dims: std::collections::BTreeMap<String, usize> = registry
                    .names()
                    .iter()
                    .map(|n| (n.clone(), registry.get(n).expect("registered").robot.dof()))
                    .collect();
                let coord = std::sync::Arc::new(coord);
                let server = match crate::net::NetServer::start(
                    std::sync::Arc::clone(&coord),
                    dims,
                    listen,
                    args.opt("tee"),
                    &spec,
                    batch,
                    window_us as u64,
                ) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("cannot listen on {listen}: {e}");
                        return 1;
                    }
                };
                match args.opt("tee") {
                    Some(path) => {
                        println!("\nlistening on {} (JSONL wire), tee → {path}", server.addr())
                    }
                    None => println!("\nlistening on {} (JSONL wire)", server.addr()),
                }
                let code = crate::net::self_drive(server.addr(), &registry, &coord, dt);
                server.stop();
                let snap = coord.obs().snapshot();
                println!(
                    "wire: malformed lines {}  slow-reader kills {}  egress high-water {}",
                    snap.counters.get("net_malformed_lines_total").copied().unwrap_or(0),
                    snap.counters.get("net_slow_reader_kills_total").copied().unwrap_or(0),
                    snap.gauges.get("net_egress_queue_highwater").copied().unwrap_or(0)
                );
                export_trace(args, coord.obs());
                return code;
            }
            export_trace(args, coord.obs());
            0
        }
        "pjrt" => {
            // Multi-robot registries and trajectory routes are native-only.
            if args.opt("robots").is_some() {
                eprintln!("warning: --robots is ignored with --backend pjrt (use --robot NAME)");
            }
            if args.opt("traj").is_some() {
                eprintln!("warning: --traj is ignored with --backend pjrt (native backend only)");
            }
            let robot_name = args.opt_or("robot", "iiwa").to_string();
            let robot = match crate::model::builtin_robot(&robot_name) {
                Some(r) => r,
                None => {
                    eprintln!("unknown robot '{robot_name}'");
                    return 2;
                }
            };
            match start_pjrt(args, &robot_name, robot.dof(), window_us as u64) {
                Ok(coord) => {
                    let mut reg = RobotRegistry::new();
                    reg.register(robot, BackendKind::Native, batch);
                    run_native_workload(&coord, &reg, requests, 0, 1e-3)
                }
                Err(code) => code,
            }
        }
        other => {
            eprintln!("unknown backend '{other}' (try native|pjrt)");
            2
        }
    }
}

/// Drain the span rings and write the Chrome trace-event JSON export to
/// the `--trace PATH` file. No-op unless both the flag was given and
/// tracing was actually enabled.
fn export_trace(args: &Args, obs: &crate::obs::ObsHub) {
    let Some(path) = args.opt("trace") else { return };
    let Some(sink) = obs.trace() else { return };
    let records = sink.drain();
    let json = crate::obs::chrome_trace_json(&records);
    match std::fs::write(path, json) {
        Ok(()) => println!(
            "trace: {} spans -> {path} (dropped {})",
            records.len(),
            sink.dropped_spans()
        ),
        Err(e) => eprintln!("trace: cannot write {path}: {e}"),
    }
}

/// Synthetic control-loop workload over every registered robot:
/// round-robin RNEA step requests (validated against the backend's own
/// reference kernel — f64 RNEA for native robots, `quant_rnea` for
/// quantized ones), a fused `dyn_all` cold/warm probe per robot (the
/// warm repeat must be served out of the kinematics memo and be bitwise
/// identical to the cold response), plus optional trajectory rollouts.
fn run_native_workload(
    coord: &Coordinator,
    registry: &RobotRegistry,
    requests: usize,
    traj: usize,
    dt: f64,
) -> i32 {
    let names = registry.names();
    let mut rng = Rng::new(2025);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for k in 0..requests {
        let name = &names[k % names.len()];
        let entry = registry.get(name).expect("registered");
        let n = entry.robot.dof();
        let s = State::random(&entry.robot, &mut rng);
        let qdd: Vec<f64> = rng.vec_range(n, -2.0, 2.0);
        let ops: Vec<Vec<f32>> = vec![
            s.q.iter().map(|&x| x as f32).collect(),
            s.qd.iter().map(|&x| x as f32).collect(),
            qdd.iter().map(|&x| x as f32).collect(),
        ];
        let rx = coord.submit_to(name, ArtifactFn::Rnea, ops);
        pending.push((name.clone(), s, qdd, rx));
    }
    let mut max_err = 0.0f64;
    let mut done = 0usize;
    for (name, s, qdd, rx) in pending {
        match rx.recv() {
            Ok(Ok(out)) => {
                done += 1;
                let entry = registry.get(&name).expect("registered");
                let n = entry.robot.dof();
                // Reference on the f32-rounded operands the engine saw,
                // through the same kernel the backend runs.
                let qr: Vec<f64> = s.q.iter().map(|&x| x as f32 as f64).collect();
                let qdr: Vec<f64> = s.qd.iter().map(|&x| x as f32 as f64).collect();
                let ur: Vec<f64> = qdd.iter().map(|&x| x as f32 as f64).collect();
                let want = match entry.backend {
                    BackendKind::Native => crate::dynamics::rnea(&entry.robot, &qr, &qdr, &ur, None),
                    BackendKind::NativeQuant(fmt) => quant_rnea(&entry.robot, &qr, &qdr, &ur, fmt),
                    BackendKind::NativeInt(fmt) => {
                        quant_rnea_i64(&entry.robot, &qr, &qdr, &ur, fmt)
                    }
                };
                for i in 0..n {
                    let scale = 1.0f64.max(want[i].abs());
                    max_err = max_err.max((out[i] as f64 - want[i]).abs() / scale);
                }
            }
            Ok(Err(e)) => {
                eprintln!("request failed: {e}");
                return 1;
            }
            Err(e) => {
                eprintln!("coordinator dropped a request: {e}");
                return 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = coord.stats();
    println!(
        "\ncompleted {done}/{requests} requests in {:.1} ms  ({:.0} req/s)",
        wall * 1e3,
        done as f64 / wall
    );
    println!(
        "batches: {}  mean fill: {:.1}%  p50 latency: {:.0} µs  p95: {:.0} µs  p99: {:.0} µs",
        st.batches,
        st.mean_fill * 100.0,
        st.p50_latency_us,
        st.p95_latency_us,
        st.p99_latency_us
    );
    println!(
        "batch fill: p50 {:.0}% p99 {:.0}%  batch exec: p50 {:.0} µs p99 {:.0} µs",
        st.fill_p50 * 100.0,
        st.fill_p99 * 100.0,
        st.exec_p50_us,
        st.exec_p99_us
    );
    if st.rejected + st.expired + st.shed > 0 {
        println!(
            "overload: rejected {}  expired {}  shed {}  breaker trips {}",
            st.rejected, st.expired, st.shed, st.breaker_trips
        );
    }
    println!("max relative error vs backend reference kernels: {max_err:.2e}");
    let mut code = 0;
    if max_err > 1e-3 {
        eprintln!("NUMERIC MISMATCH between served and reference implementation");
        code = 1;
    }

    // Fused-route probe: every robot answers one `dyn_all` request cold
    // (a kinematics-memo miss) and then the bitwise-identical request
    // warm. The warm repeat must match the cold response byte for byte —
    // the serving-level statement of the hit ≡ miss proof — and with
    // serial routes it must land in the memo (hits > 0).
    if code == 0 {
        let mut warm_checked = 0usize;
        for name in &names {
            let entry = registry.get(name).expect("registered");
            let n = entry.robot.dof();
            let s = State::random(&entry.robot, &mut rng);
            let tau: Vec<f64> = rng.vec_range(n, -2.0, 2.0);
            let ops: Vec<Vec<f32>> = vec![
                s.q.iter().map(|&x| x as f32).collect(),
                s.qd.iter().map(|&x| x as f32).collect(),
                tau.iter().map(|&x| x as f32).collect(),
            ];
            let cold = match coord.submit_to(name, ArtifactFn::DynAll, ops.clone()).recv() {
                Ok(Ok(out)) => out,
                Ok(Err(e)) => {
                    eprintln!("dyn_all {name} failed: {e}");
                    return 1;
                }
                Err(e) => {
                    eprintln!("dyn_all {name} dropped: {e}");
                    return 1;
                }
            };
            let want_len = n * n + 2 * n;
            if cold.len() != want_len || cold.iter().any(|x| !x.is_finite()) {
                eprintln!(
                    "dyn_all {name}: malformed fused response ({} of {want_len} values)",
                    cold.len()
                );
                return 1;
            }
            let warm = match coord.submit_to(name, ArtifactFn::DynAll, ops).recv() {
                Ok(Ok(out)) => out,
                Ok(Err(e)) => {
                    eprintln!("dyn_all {name} warm repeat failed: {e}");
                    return 1;
                }
                Err(e) => {
                    eprintln!("dyn_all {name} warm repeat dropped: {e}");
                    return 1;
                }
            };
            if warm != cold {
                eprintln!("dyn_all {name}: warm (memo-hit) response differs bitwise from cold");
                return 1;
            }
            warm_checked += 1;
        }
        let st = coord.stats();
        println!(
            "dyn_all memo: hits {} misses {}  ({warm_checked} warm repeats bitwise == cold)",
            st.memo_hits, st.memo_misses
        );
    }

    // Deadline probes: one request per robot with an already-expired
    // deadline must be refused with the structured `Expired` error
    // (never executed), while an in-deadline twin on the same route
    // still completes — expiry exercised on live lanes, not only in the
    // loadgen harness.
    if code == 0 {
        let mut expired_ok = 0usize;
        for name in &names {
            let entry = registry.get(name).expect("registered");
            let n = entry.robot.dof();
            let mut mk = || -> Vec<Vec<f32>> {
                (0..3)
                    .map(|_| rng.vec_range(n, -1.0, 1.0).iter().map(|&x| x as f32).collect())
                    .collect()
            };
            let stale = coord.submit_to_opts(name, ArtifactFn::Fd, mk(), SubmitOptions::deadline_us(0));
            let fresh =
                coord.submit_to_opts(name, ArtifactFn::Fd, mk(), SubmitOptions::deadline_us(5_000_000));
            match stale.recv() {
                Ok(Err(ServeError::Expired { deadline_us: 0, .. })) => expired_ok += 1,
                Ok(Ok(_)) => {
                    eprintln!("deadline probe {name}: executed despite an expired deadline");
                    return 1;
                }
                Ok(Err(e)) => {
                    eprintln!("deadline probe {name}: unexpected refusal: {e}");
                    return 1;
                }
                Err(e) => {
                    eprintln!("deadline probe {name}: dropped: {e}");
                    return 1;
                }
            }
            match fresh.recv() {
                Ok(Ok(out)) if out.len() == n => {}
                other => {
                    eprintln!("deadline probe {name}: in-deadline twin failed: {other:?}");
                    return 1;
                }
            }
        }
        println!(
            "deadline probes: {expired_ok}/{} expired on live lanes, in-deadline twins completed",
            names.len()
        );
    }

    if traj > 0 && code == 0 {
        let t1 = Instant::now();
        let mut traj_pending = Vec::new();
        for name in &names {
            let entry = registry.get(name).expect("registered");
            let n = entry.robot.dof();
            let s = State::random(&entry.robot, &mut rng);
            let req = TrajRequest {
                q0: s.q.iter().map(|&x| x as f32).collect(),
                qd0: s.qd.iter().map(|&x| x as f32).collect(),
                tau: rng.vec_range(traj * n, -2.0, 2.0).iter().map(|&x| x as f32).collect(),
                dt,
            };
            traj_pending.push((name.clone(), n, coord.submit_traj(name, req)));
        }
        for (name, n, rx) in traj_pending {
            match rx.recv() {
                Ok(Ok(out)) if out.len() == 2 * traj * n && out.iter().all(|x| x.is_finite()) => {
                    println!(
                        "trajectory {name}: H={traj} rollout ok ({} samples, {:.1} ms)",
                        out.len(),
                        t1.elapsed().as_secs_f64() * 1e3
                    );
                }
                Ok(Ok(out)) => {
                    eprintln!("trajectory {name}: malformed response ({} samples)", out.len());
                    code = 1;
                }
                Ok(Err(e)) => {
                    eprintln!("trajectory {name} failed: {e}");
                    code = 1;
                }
                Err(e) => {
                    eprintln!("trajectory {name} dropped: {e}");
                    code = 1;
                }
            }
        }
    }
    code
}

#[cfg(feature = "pjrt")]
fn start_pjrt(args: &Args, robot_name: &str, n: usize, window_us: u64) -> Result<Coordinator, i32> {
    use crate::runtime::artifact::scan_artifacts;
    use std::path::Path;
    let dir = args.opt_or("artifacts", "artifacts").to_string();
    let artifacts: Vec<_> = scan_artifacts(Path::new(&dir))
        .into_iter()
        .filter(|a| a.robot == robot_name)
        .collect();
    if artifacts.is_empty() {
        eprintln!("no artifacts for '{robot_name}' under {dir}/ — run `make artifacts` first");
        return Err(1);
    }
    println!("serving {} with {} artifact(s):", robot_name, artifacts.len());
    for a in &artifacts {
        println!("  {} ({}, batch {})", a.path.display(), a.function.name(), a.batch);
    }
    Ok(Coordinator::start_pjrt(artifacts, n, window_us))
}

#[cfg(not(feature = "pjrt"))]
fn start_pjrt(_args: &Args, _robot_name: &str, _n: usize, _window_us: u64) -> Result<Coordinator, i32> {
    eprintln!("the pjrt backend requires building with `--features pjrt` (and the xla crate)");
    Err(2)
}
