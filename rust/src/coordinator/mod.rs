//! Serving coordinator: request routing + dynamic batching — the L3
//! system layer. Mirrors the accelerator's operating model: the RTP
//! pipeline reaches peak throughput only when tasks are batched through
//! it, so the coordinator aggregates concurrent control requests into
//! fixed-size batches per (robot, function) route, pads partial batches,
//! and fans results back out.
//!
//! Two backends: the **native** workspace engine (default — no artifacts,
//! no external toolchain; one allocation-free `DynWorkspace` per worker
//! thread) and, behind the `pjrt` feature, AOT-compiled HLO artifacts
//! executed through PJRT.
//!
//! Threading: PJRT client/executable handles are not `Send`, and the
//! native workspace is deliberately thread-local, so each worker thread
//! owns its own executor; requests cross threads through channels.

pub mod batcher;
pub mod stats;

pub use batcher::{BackendSpec, Coordinator, Job, JobResult};
pub use stats::ServeStats;

use crate::model::builtin_robot;
use crate::runtime::artifact::ArtifactFn;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use std::time::Instant;

/// `draco serve`: bring up the coordinator, push a synthetic workload
/// through it, verify numerics against the reference implementation, and
/// report latency/throughput. `--backend native` (default) serves from
/// the workspace core; `--backend pjrt` needs artifacts + the feature.
pub fn serve_cli(args: &Args) -> i32 {
    let robot_name = args.opt_or("robot", "iiwa").to_string();
    let backend = args.opt_or("backend", "native").to_string();
    let requests = args.opt_usize("requests", 512);
    let window_us = args.opt_usize("window-us", 200);

    let robot = match builtin_robot(&robot_name) {
        Some(r) => r,
        None => {
            eprintln!("unknown robot '{robot_name}'");
            return 2;
        }
    };

    let coord = match backend.as_str() {
        "native" => {
            let batch = args.opt_usize("batch", 64);
            println!(
                "serving {robot_name} natively (workspace core): rnea/fd/minv, batch {batch}"
            );
            Coordinator::start_native(
                &robot,
                &[
                    (ArtifactFn::Rnea, batch),
                    (ArtifactFn::Fd, batch),
                    (ArtifactFn::Minv, batch),
                ],
                window_us as u64,
            )
        }
        "pjrt" => match start_pjrt(args, &robot_name, robot.dof(), window_us as u64) {
            Ok(c) => c,
            Err(code) => return code,
        },
        other => {
            eprintln!("unknown backend '{other}' (try native|pjrt)");
            return 2;
        }
    };

    // Synthetic control-loop workload: random in-limit states.
    let mut rng = Rng::new(2025);
    let n = robot.dof();
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for _ in 0..requests {
        let s = crate::model::State::random(&robot, &mut rng);
        let qdd: Vec<f64> = rng.vec_range(n, -2.0, 2.0);
        let ops: Vec<Vec<f32>> = vec![
            s.q.iter().map(|&x| x as f32).collect(),
            s.qd.iter().map(|&x| x as f32).collect(),
            qdd.iter().map(|&x| x as f32).collect(),
        ];
        let rx = coord.submit(ArtifactFn::Rnea, ops.clone());
        pending.push((s, qdd, rx));
    }
    let mut max_err = 0.0f64;
    let mut done = 0usize;
    for (s, qdd, rx) in pending {
        match rx.recv() {
            Ok(Ok(out)) => {
                done += 1;
                let want = crate::dynamics::rnea(&robot, &s.q, &s.qd, &qdd, None);
                for i in 0..n {
                    let scale = 1.0f64.max(want[i].abs());
                    max_err = max_err.max((out[i] as f64 - want[i]).abs() / scale);
                }
            }
            Ok(Err(e)) => {
                eprintln!("request failed: {e}");
                return 1;
            }
            Err(e) => {
                eprintln!("coordinator dropped a request: {e}");
                return 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = coord.stats();
    println!(
        "\ncompleted {done}/{requests} requests in {:.1} ms  ({:.0} req/s)",
        wall * 1e3,
        done as f64 / wall
    );
    println!(
        "batches: {}  mean fill: {:.1}%  p50 latency: {:.0} µs  p95: {:.0} µs",
        st.batches,
        st.mean_fill * 100.0,
        st.p50_latency_us,
        st.p95_latency_us
    );
    println!("max relative error vs native f64 RNEA: {max_err:.2e}");
    coord.shutdown();
    if max_err > 1e-3 {
        eprintln!("NUMERIC MISMATCH between served and reference implementation");
        return 1;
    }
    0
}

#[cfg(feature = "pjrt")]
fn start_pjrt(args: &Args, robot_name: &str, n: usize, window_us: u64) -> Result<Coordinator, i32> {
    use crate::runtime::artifact::scan_artifacts;
    use std::path::Path;
    let dir = args.opt_or("artifacts", "artifacts").to_string();
    let artifacts: Vec<_> = scan_artifacts(Path::new(&dir))
        .into_iter()
        .filter(|a| a.robot == robot_name)
        .collect();
    if artifacts.is_empty() {
        eprintln!("no artifacts for '{robot_name}' under {dir}/ — run `make artifacts` first");
        return Err(1);
    }
    println!("serving {} with {} artifact(s):", robot_name, artifacts.len());
    for a in &artifacts {
        println!("  {} ({}, batch {})", a.path.display(), a.function.name(), a.batch);
    }
    Ok(Coordinator::start_pjrt(artifacts, n, window_us))
}

#[cfg(not(feature = "pjrt"))]
fn start_pjrt(_args: &Args, _robot_name: &str, _n: usize, _window_us: u64) -> Result<Coordinator, i32> {
    eprintln!("the pjrt backend requires building with `--features pjrt` (and the xla crate)");
    Err(2)
}
