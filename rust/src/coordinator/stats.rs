//! Serving statistics: request latency distribution and batch fill.

/// Mutable accumulator the workers feed; shared behind a mutex.
#[derive(Debug, Default)]
pub struct StatsInner {
    /// Requests answered successfully.
    pub completed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Sum of per-batch fill fractions (for the mean).
    pub fill_sum: f64,
    /// Sum of per-batch execution times [µs].
    pub exec_us_sum: f64,
    /// Request latencies [µs]; bounded reservoir (first 65536).
    pub latencies_us: Vec<f64>,
}

impl StatsInner {
    /// Record one completed request's queue-to-answer latency.
    pub fn record(&mut self, latency_us: f64) {
        self.completed += 1;
        if self.latencies_us.len() < 65536 {
            self.latencies_us.push(latency_us);
        }
    }

    /// Record one executed batch (fill fraction and execution time).
    pub fn record_batch(&mut self, fill: f64, exec_us: f64) {
        self.batches += 1;
        self.fill_sum += fill;
        self.exec_us_sum += exec_us;
    }

    /// Freeze the current counters into an immutable snapshot.
    pub fn snapshot(&self) -> ServeStats {
        let mut lat = self.latencies_us.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                0.0
            } else {
                lat[((lat.len() as f64 * p) as usize).min(lat.len() - 1)]
            }
        };
        ServeStats {
            completed: self.completed,
            batches: self.batches,
            mean_fill: if self.batches > 0 { self.fill_sum / self.batches as f64 } else { 0.0 },
            mean_exec_us: if self.batches > 0 {
                self.exec_us_sum / self.batches as f64
            } else {
                0.0
            },
            p50_latency_us: pct(0.50),
            p95_latency_us: pct(0.95),
        }
    }
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    /// Requests answered successfully.
    pub completed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Mean batch fill fraction (1.0 = every batch full).
    pub mean_fill: f64,
    /// Mean per-batch execution time [µs].
    pub mean_exec_us: f64,
    /// Median request latency [µs].
    pub p50_latency_us: f64,
    /// 95th-percentile request latency [µs].
    pub p95_latency_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = StatsInner::default();
        for i in 0..100 {
            s.record(i as f64);
        }
        s.record_batch(0.5, 10.0);
        s.record_batch(1.0, 20.0);
        let snap = s.snapshot();
        assert_eq!(snap.completed, 100);
        assert_eq!(snap.batches, 2);
        assert!((snap.mean_fill - 0.75).abs() < 1e-12);
        assert!(snap.p50_latency_us <= snap.p95_latency_us);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let snap = StatsInner::default().snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.p95_latency_us, 0.0);
    }
}
