//! Serving statistics: request latency distribution and batch fill.

#[derive(Debug, Default)]
pub struct StatsInner {
    pub completed: u64,
    pub batches: u64,
    pub fill_sum: f64,
    pub exec_us_sum: f64,
    /// Request latencies [µs]; bounded reservoir (first 65536).
    pub latencies_us: Vec<f64>,
}

impl StatsInner {
    pub fn record(&mut self, latency_us: f64) {
        self.completed += 1;
        if self.latencies_us.len() < 65536 {
            self.latencies_us.push(latency_us);
        }
    }

    pub fn record_batch(&mut self, fill: f64, exec_us: f64) {
        self.batches += 1;
        self.fill_sum += fill;
        self.exec_us_sum += exec_us;
    }

    pub fn snapshot(&self) -> ServeStats {
        let mut lat = self.latencies_us.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                0.0
            } else {
                lat[((lat.len() as f64 * p) as usize).min(lat.len() - 1)]
            }
        };
        ServeStats {
            completed: self.completed,
            batches: self.batches,
            mean_fill: if self.batches > 0 { self.fill_sum / self.batches as f64 } else { 0.0 },
            mean_exec_us: if self.batches > 0 {
                self.exec_us_sum / self.batches as f64
            } else {
                0.0
            },
            p50_latency_us: pct(0.50),
            p95_latency_us: pct(0.95),
        }
    }
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    pub completed: u64,
    pub batches: u64,
    pub mean_fill: f64,
    pub mean_exec_us: f64,
    pub p50_latency_us: f64,
    pub p95_latency_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = StatsInner::default();
        for i in 0..100 {
            s.record(i as f64);
        }
        s.record_batch(0.5, 10.0);
        s.record_batch(1.0, 20.0);
        let snap = s.snapshot();
        assert_eq!(snap.completed, 100);
        assert_eq!(snap.batches, 2);
        assert!((snap.mean_fill - 0.75).abs() < 1e-12);
        assert!(snap.p50_latency_us <= snap.p95_latency_us);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let snap = StatsInner::default().snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.p95_latency_us, 0.0);
    }
}
