//! Serving statistics: request latency distributions (aggregate and
//! per QoS class), batch fill, and the overload counters (rejected /
//! expired / shed) the admission-control layer feeds.

use super::qos::QosClass;
use crate::util::rng::Rng;
use std::sync::{Mutex, MutexGuard};

/// Reservoir capacity for each latency sample. Bounded memory no matter
/// how long the server runs.
const RESERVOIR: usize = 65536;

/// Lock the shared stats accumulator, recovering from poisoning: a
/// recorder that panicked while holding the lock must degrade to
/// slightly-stale counters, not wedge every later `stats()` /
/// record / shutdown path with a cascading `unwrap` panic. The inner
/// state is a plain accumulator (counters + reservoirs), so observing a
/// half-applied record is harmless.
pub(crate) fn lock_stats(stats: &Mutex<StatsInner>) -> MutexGuard<'_, StatsInner> {
    stats.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Seeded uniform latency reservoir (Vitter's Algorithm R) over every
/// value ever recorded — not the first `RESERVOIR`, which would freeze
/// the percentiles on startup traffic.
#[derive(Debug)]
struct Reservoir {
    /// The current sample (bounded by `RESERVOIR`).
    samples: Vec<f64>,
    /// Values recorded so far (the sampling denominator).
    seen: u64,
    /// Seeded PRNG driving replacement — deterministic across runs for a
    /// given record sequence.
    rng: Rng,
}

impl Reservoir {
    fn new(seed: u64) -> Reservoir {
        Reservoir { samples: Vec::new(), seen: 0, rng: Rng::new(seed) }
    }

    fn record(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR {
            self.samples.push(x);
        } else {
            // Algorithm R: keep the newcomer with probability K/seen by
            // replacing a uniformly random slot.
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < RESERVOIR {
                self.samples[j] = x;
            }
        }
    }

    /// Nearest-rank percentiles over a sorted copy of the sample.
    /// `total_cmp` keeps the sort total when a non-finite latency slips
    /// in (NaNs order after +∞) — a poisoned sample must never panic the
    /// snapshot path.
    fn percentiles<const K: usize>(&self, ps: [f64; K]) -> [f64; K] {
        let mut lat = self.samples.clone();
        lat.sort_by(f64::total_cmp);
        ps.map(|p| nearest_rank(&lat, p))
    }
}

/// Nearest-rank percentile: the ⌈p·len⌉-th smallest value (1-based), so
/// `nearest_rank(v, 0.5)` over 100 samples reads index 49.
fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

/// Mutable accumulator the workers feed; shared behind a mutex (see
/// [`lock_stats`] for the poison-recovering access path).
#[derive(Debug)]
pub struct StatsInner {
    /// Requests answered successfully (all classes).
    pub completed: u64,
    /// Batches executed (including failed executions — they consumed a
    /// batch slot and wall clock).
    pub batches: u64,
    /// Sum of per-batch fill fractions (for the mean).
    pub fill_sum: f64,
    /// Sum of per-batch execution times [µs].
    pub exec_us_sum: f64,
    /// Jobs refused at admission (class queue full).
    pub rejected: u64,
    /// Jobs dropped at batch formation because their deadline had
    /// passed — never executed.
    pub expired: u64,
    /// Jobs refused at admission because the route's circuit breaker was
    /// open.
    pub shed: u64,
    /// Jobs dropped at batch formation because their consumer (a
    /// disconnected network peer) was gone — never executed.
    pub cancelled: u64,
    /// Circuit-breaker trips (including re-trips of failed half-open
    /// probes).
    pub breaker_trips: u64,
    /// Cross-request kinematics-memo hits across every `dyn_all` route
    /// (serial engine memos plus pooled per-worker memo deltas).
    pub memo_hits: u64,
    /// Cross-request kinematics-memo misses (each miss ran the full
    /// sweep and populated the memo).
    pub memo_misses: u64,
    /// Aggregate latency reservoir over every completed request.
    all_lat: Reservoir,
    /// Completions per QoS class, indexed by [`QosClass::index`].
    class_completed: [u64; 3],
    /// Per-class latency reservoirs, indexed by [`QosClass::index`].
    class_lat: [Reservoir; 3],
    /// Batch fill-fraction reservoir (one sample per executed batch).
    fill: Reservoir,
    /// Batch execution-time reservoir [µs].
    exec_us: Reservoir,
}

impl Default for StatsInner {
    fn default() -> StatsInner {
        StatsInner {
            completed: 0,
            batches: 0,
            fill_sum: 0.0,
            exec_us_sum: 0.0,
            rejected: 0,
            expired: 0,
            shed: 0,
            cancelled: 0,
            breaker_trips: 0,
            memo_hits: 0,
            memo_misses: 0,
            all_lat: Reservoir::new(0x5EED_1A7E),
            class_completed: [0; 3],
            class_lat: [
                Reservoir::new(0x5EED_1A7E ^ 1),
                Reservoir::new(0x5EED_1A7E ^ 2),
                Reservoir::new(0x5EED_1A7E ^ 3),
            ],
            fill: Reservoir::new(0x5EED_1A7E ^ 4),
            exec_us: Reservoir::new(0x5EED_1A7E ^ 5),
        }
    }
}

impl StatsInner {
    /// Record one completed request's queue-to-answer latency under its
    /// QoS class.
    pub fn record(&mut self, class: QosClass, latency_us: f64) {
        self.completed += 1;
        self.all_lat.record(latency_us);
        let i = class.index();
        self.class_completed[i] += 1;
        self.class_lat[i].record(latency_us);
    }

    /// Record one executed batch (fill fraction and execution time).
    pub fn record_batch(&mut self, fill: f64, exec_us: f64) {
        self.batches += 1;
        self.fill_sum += fill;
        self.exec_us_sum += exec_us;
        self.fill.record(fill);
        self.exec_us.record(exec_us);
    }

    /// Freeze the current counters into an immutable snapshot.
    pub fn snapshot(&self) -> ServeStats {
        let [p50, p95, p99] = self.all_lat.percentiles([0.50, 0.95, 0.99]);
        let [fill_p50, fill_p99] = self.fill.percentiles([0.50, 0.99]);
        let [exec_p50_us, exec_p99_us] = self.exec_us.percentiles([0.50, 0.99]);
        let mut per_class = [ClassStats::default(); 3];
        for c in QosClass::ALL {
            let i = c.index();
            let [p50, p99, p999] = self.class_lat[i].percentiles([0.50, 0.99, 0.999]);
            per_class[i] = ClassStats {
                completed: self.class_completed[i],
                p50_latency_us: p50,
                p99_latency_us: p99,
                p999_latency_us: p999,
            };
        }
        ServeStats {
            completed: self.completed,
            batches: self.batches,
            mean_fill: if self.batches > 0 { self.fill_sum / self.batches as f64 } else { 0.0 },
            mean_exec_us: if self.batches > 0 {
                self.exec_us_sum / self.batches as f64
            } else {
                0.0
            },
            fill_p50,
            fill_p99,
            exec_p50_us,
            exec_p99_us,
            p50_latency_us: p50,
            p95_latency_us: p95,
            p99_latency_us: p99,
            rejected: self.rejected,
            expired: self.expired,
            shed: self.shed,
            cancelled: self.cancelled,
            breaker_trips: self.breaker_trips,
            memo_hits: self.memo_hits,
            memo_misses: self.memo_misses,
            per_class,
        }
    }
}

/// Per-QoS-class slice of a [`ServeStats`] snapshot.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassStats {
    /// Requests of this class answered successfully.
    pub completed: u64,
    /// Median request latency [µs].
    pub p50_latency_us: f64,
    /// 99th-percentile request latency [µs].
    pub p99_latency_us: f64,
    /// 99.9th-percentile request latency [µs].
    pub p999_latency_us: f64,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    /// Requests answered successfully (all classes).
    pub completed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Mean batch fill fraction (1.0 = every batch full).
    pub mean_fill: f64,
    /// Mean per-batch execution time [µs].
    pub mean_exec_us: f64,
    /// Median batch fill fraction (1.0 = every batch full).
    pub fill_p50: f64,
    /// 99th-percentile batch fill fraction.
    pub fill_p99: f64,
    /// Median per-batch execution time [µs].
    pub exec_p50_us: f64,
    /// 99th-percentile per-batch execution time [µs].
    pub exec_p99_us: f64,
    /// Median request latency [µs], all classes.
    pub p50_latency_us: f64,
    /// 95th-percentile request latency [µs], all classes.
    pub p95_latency_us: f64,
    /// 99th-percentile request latency [µs], all classes.
    pub p99_latency_us: f64,
    /// Jobs refused at admission (class queue full).
    pub rejected: u64,
    /// Jobs dropped at batch formation past their deadline (never
    /// executed).
    pub expired: u64,
    /// Jobs refused because the route's circuit breaker was open.
    pub shed: u64,
    /// Jobs dropped at batch formation because their consumer was gone
    /// (disconnected network peer) — never executed.
    pub cancelled: u64,
    /// Circuit-breaker trips.
    pub breaker_trips: u64,
    /// Kinematics-memo hits across every `dyn_all` route (repeated
    /// linearizations answered without re-running the sweep).
    pub memo_hits: u64,
    /// Kinematics-memo misses (full sweeps that populated the memo).
    pub memo_misses: u64,
    /// Per-class completions and latency percentiles, indexed by
    /// [`QosClass::index`].
    pub per_class: [ClassStats; 3],
}

impl ServeStats {
    /// The per-class slice for `class`.
    pub fn class(&self, class: QosClass) -> &ClassStats {
        &self.per_class[class.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = StatsInner::default();
        for i in 0..100 {
            s.record(QosClass::Interactive, i as f64);
        }
        s.record_batch(0.5, 10.0);
        s.record_batch(1.0, 20.0);
        let snap = s.snapshot();
        assert_eq!(snap.completed, 100);
        assert_eq!(snap.batches, 2);
        assert!((snap.mean_fill - 0.75).abs() < 1e-12);
        // Two batch samples: nearest-rank p50 is the first (0.5 / 10µs),
        // p99 the second (1.0 / 20µs).
        assert_eq!(snap.fill_p50, 0.5);
        assert_eq!(snap.fill_p99, 1.0);
        assert_eq!(snap.exec_p50_us, 10.0);
        assert_eq!(snap.exec_p99_us, 20.0);
        assert!(snap.p50_latency_us <= snap.p95_latency_us);
        assert!(snap.p95_latency_us <= snap.p99_latency_us);
        // Everything was interactive; the other class slices stay empty.
        assert_eq!(snap.class(QosClass::Interactive).completed, 100);
        assert_eq!(snap.class(QosClass::Control).completed, 0);
        assert_eq!(snap.class(QosClass::Control).p99_latency_us, 0.0);
    }

    /// Nearest-rank percentiles: over samples 0..100 the median is the
    /// 50th smallest = 49.0 (the pre-fix truncation indexed 50).
    #[test]
    fn nearest_rank_indexing() {
        let mut s = StatsInner::default();
        for i in 0..100 {
            s.record(QosClass::Control, i as f64);
        }
        let snap = s.snapshot();
        assert_eq!(snap.p50_latency_us, 49.0);
        assert_eq!(snap.p95_latency_us, 94.0);
        assert_eq!(snap.class(QosClass::Control).p50_latency_us, 49.0);
        assert_eq!(snap.class(QosClass::Control).p99_latency_us, 98.0);
        // Single sample: every percentile is that sample.
        let mut one = StatsInner::default();
        one.record(QosClass::Bulk, 7.0);
        let snap = one.snapshot();
        assert_eq!(snap.p50_latency_us, 7.0);
        assert_eq!(snap.p95_latency_us, 7.0);
        assert_eq!(snap.class(QosClass::Bulk).p999_latency_us, 7.0);
    }

    /// Regression: a NaN latency sample must not panic the percentile
    /// sort (the old `partial_cmp(..).unwrap()` did). `total_cmp` orders
    /// NaN after +∞, so the finite percentiles stay meaningful.
    #[test]
    fn nan_sample_does_not_panic_snapshot() {
        let mut s = StatsInner::default();
        s.record(QosClass::Interactive, 1.0);
        s.record(QosClass::Interactive, f64::NAN);
        s.record(QosClass::Interactive, 2.0);
        s.record(QosClass::Interactive, 3.0);
        let snap = s.snapshot(); // must not panic
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.p50_latency_us, 2.0, "NaN sorts last; the median stays finite");
        assert!(!snap.p50_latency_us.is_nan());
    }

    /// Under sustained load the reservoir must keep sampling: late
    /// requests appear and the percentiles track the whole run, not the
    /// first 65536 (where a truncating buffer would freeze — with
    /// ascending latencies it would report p50 ≈ 32768 forever).
    #[test]
    fn reservoir_samples_whole_run() {
        let mut s = StatsInner::default();
        let total = 200_000u64;
        for i in 0..total {
            s.record(QosClass::Bulk, i as f64);
        }
        assert_eq!(s.completed, total);
        assert_eq!(s.all_lat.samples.len(), RESERVOIR, "reservoir stays bounded");
        assert!(
            s.all_lat.samples.iter().any(|&x| x > 150_000.0),
            "late latencies must be sampled"
        );
        let snap = s.snapshot();
        // True p50/p95 of 0..200000 are ~100000/~190000; a uniform
        // reservoir of 65536 samples lands well within ±5%.
        assert!((snap.p50_latency_us - 100_000.0).abs() < 5_000.0, "p50 {}", snap.p50_latency_us);
        assert!((snap.p95_latency_us - 190_000.0).abs() < 5_000.0, "p95 {}", snap.p95_latency_us);
        // The class reservoir saw the same stream (its own seed).
        assert_eq!(s.class_lat[QosClass::Bulk.index()].samples.len(), RESERVOIR);
        assert!(
            (snap.class(QosClass::Bulk).p50_latency_us - 100_000.0).abs() < 5_000.0,
            "class p50 {}",
            snap.class(QosClass::Bulk).p50_latency_us
        );
    }

    /// Same record sequence ⇒ same reservoir (seeded, deterministic).
    #[test]
    fn reservoir_is_deterministic() {
        let run = || {
            let mut s = StatsInner::default();
            for i in 0..100_000 {
                s.record(QosClass::Interactive, i as f64);
            }
            s.all_lat.samples
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let snap = StatsInner::default().snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.p95_latency_us, 0.0);
        assert_eq!(snap.fill_p50, 0.0);
        assert_eq!(snap.exec_p99_us, 0.0);
        assert_eq!(snap.rejected, 0);
        assert_eq!(snap.expired, 0);
        assert_eq!(snap.shed, 0);
    }

    /// A recorder that panicked while holding the stats lock poisons the
    /// mutex; [`lock_stats`] must recover the inner state instead of
    /// cascading the panic into every later `stats()` call.
    #[test]
    fn lock_stats_recovers_from_poison() {
        let m = Mutex::new(StatsInner::default());
        lock_stats(&m).record(QosClass::Control, 5.0);
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = m.lock().unwrap();
            panic!("injected recorder panic");
        }));
        assert!(poison.is_err());
        assert!(m.is_poisoned(), "mutex must actually be poisoned for this test");
        // Degraded access still works: record and snapshot proceed.
        lock_stats(&m).record(QosClass::Control, 6.0);
        let snap = lock_stats(&m).snapshot();
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.class(QosClass::Control).completed, 2);
    }
}
