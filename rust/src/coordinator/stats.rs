//! Serving statistics: request latency distribution and batch fill.

use crate::util::rng::Rng;

/// Reservoir capacity for the latency sample. Bounded memory no matter
/// how long the server runs.
const RESERVOIR: usize = 65536;

/// Mutable accumulator the workers feed; shared behind a mutex.
#[derive(Debug)]
pub struct StatsInner {
    /// Requests answered successfully.
    pub completed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Sum of per-batch fill fractions (for the mean).
    pub fill_sum: f64,
    /// Sum of per-batch execution times [µs].
    pub exec_us_sum: f64,
    /// Request latencies [µs]: a uniform reservoir sample (Vitter's
    /// Algorithm R) over **all** completed requests — not the first
    /// `RESERVOIR`, which would freeze p50/p95 on startup traffic.
    /// `completed` doubles as the sampling denominator (every completed
    /// request records exactly one latency).
    pub latencies_us: Vec<f64>,
    /// Seeded PRNG driving reservoir replacement — deterministic across
    /// runs for a given record sequence.
    rng: Rng,
}

impl Default for StatsInner {
    fn default() -> StatsInner {
        StatsInner {
            completed: 0,
            batches: 0,
            fill_sum: 0.0,
            exec_us_sum: 0.0,
            latencies_us: Vec::new(),
            rng: Rng::new(0x5EED_1A7E),
        }
    }
}

impl StatsInner {
    /// Record one completed request's queue-to-answer latency.
    pub fn record(&mut self, latency_us: f64) {
        self.completed += 1;
        if self.latencies_us.len() < RESERVOIR {
            self.latencies_us.push(latency_us);
        } else {
            // Algorithm R: keep the newcomer with probability K/seen by
            // replacing a uniformly random slot — every latency ever
            // recorded ends up in the reservoir with equal probability.
            let j = (self.rng.next_u64() % self.completed) as usize;
            if j < RESERVOIR {
                self.latencies_us[j] = latency_us;
            }
        }
    }

    /// Record one executed batch (fill fraction and execution time).
    pub fn record_batch(&mut self, fill: f64, exec_us: f64) {
        self.batches += 1;
        self.fill_sum += fill;
        self.exec_us_sum += exec_us;
    }

    /// Freeze the current counters into an immutable snapshot.
    pub fn snapshot(&self) -> ServeStats {
        let mut lat = self.latencies_us.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                0.0
            } else {
                // Nearest-rank: the ⌈p·len⌉-th smallest value (1-based),
                // so pct(0.5) over 100 samples reads index 49 — the old
                // `(len·p) as usize` truncation read index 50.
                let rank = (p * lat.len() as f64).ceil() as usize;
                lat[rank.saturating_sub(1).min(lat.len() - 1)]
            }
        };
        ServeStats {
            completed: self.completed,
            batches: self.batches,
            mean_fill: if self.batches > 0 { self.fill_sum / self.batches as f64 } else { 0.0 },
            mean_exec_us: if self.batches > 0 {
                self.exec_us_sum / self.batches as f64
            } else {
                0.0
            },
            p50_latency_us: pct(0.50),
            p95_latency_us: pct(0.95),
        }
    }
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    /// Requests answered successfully.
    pub completed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Mean batch fill fraction (1.0 = every batch full).
    pub mean_fill: f64,
    /// Mean per-batch execution time [µs].
    pub mean_exec_us: f64,
    /// Median request latency [µs].
    pub p50_latency_us: f64,
    /// 95th-percentile request latency [µs].
    pub p95_latency_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = StatsInner::default();
        for i in 0..100 {
            s.record(i as f64);
        }
        s.record_batch(0.5, 10.0);
        s.record_batch(1.0, 20.0);
        let snap = s.snapshot();
        assert_eq!(snap.completed, 100);
        assert_eq!(snap.batches, 2);
        assert!((snap.mean_fill - 0.75).abs() < 1e-12);
        assert!(snap.p50_latency_us <= snap.p95_latency_us);
    }

    /// Nearest-rank percentiles: over samples 0..100 the median is the
    /// 50th smallest = 49.0 (the pre-fix truncation indexed 50).
    #[test]
    fn nearest_rank_indexing() {
        let mut s = StatsInner::default();
        for i in 0..100 {
            s.record(i as f64);
        }
        let snap = s.snapshot();
        assert_eq!(snap.p50_latency_us, 49.0);
        assert_eq!(snap.p95_latency_us, 94.0);
        // Single sample: every percentile is that sample.
        let mut one = StatsInner::default();
        one.record(7.0);
        let snap = one.snapshot();
        assert_eq!(snap.p50_latency_us, 7.0);
        assert_eq!(snap.p95_latency_us, 7.0);
    }

    /// Under sustained load the reservoir must keep sampling: late
    /// requests appear and the percentiles track the whole run, not the
    /// first 65536 (where the old truncating buffer froze — with
    /// ascending latencies it would report p50 ≈ 32768 forever).
    #[test]
    fn reservoir_samples_whole_run() {
        let mut s = StatsInner::default();
        let total = 200_000u64;
        for i in 0..total {
            s.record(i as f64);
        }
        assert_eq!(s.completed, total);
        assert_eq!(s.latencies_us.len(), RESERVOIR, "reservoir stays bounded");
        assert!(
            s.latencies_us.iter().any(|&x| x > 150_000.0),
            "late latencies must be sampled"
        );
        let snap = s.snapshot();
        // True p50/p95 of 0..200000 are ~100000/~190000; a uniform
        // reservoir of 65536 samples lands well within ±5%.
        assert!((snap.p50_latency_us - 100_000.0).abs() < 5_000.0, "p50 {}", snap.p50_latency_us);
        assert!((snap.p95_latency_us - 190_000.0).abs() < 5_000.0, "p95 {}", snap.p95_latency_us);
    }

    /// Same record sequence ⇒ same reservoir (seeded, deterministic).
    #[test]
    fn reservoir_is_deterministic() {
        let run = || {
            let mut s = StatsInner::default();
            for i in 0..100_000 {
                s.record(i as f64);
            }
            s.latencies_us
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let snap = StatsInner::default().snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.p95_latency_us, 0.0);
    }
}
