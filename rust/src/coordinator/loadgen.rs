//! Open-loop load generator: the tail-latency and overload harness for
//! the serving coordinator (`draco loadgen`).
//!
//! Requests arrive on a **Poisson process at a fixed offered rate**,
//! independent of how fast the server answers (open loop — a closed
//! loop would slow its own arrivals under overload and hide the very
//! tail it is supposed to measure). The overload sweep drives a fresh
//! coordinator over a throttled [`ChaosEngine`](crate::runtime::chaos)
//! route whose capacity is pinned by construction
//! (`batch / (delay + window)`), so "offer 2× capacity" is
//! deterministic across hosts. Two `real-*` scenarios then point the
//! same Poisson front end at the real engines — the native f64 FD
//! route and the true-integer qint FD route — so the dump also carries
//! real-engine latency envelopes (these keep the expired-probe and
//! clean-traffic invariants but are expected not to shed, so they sit
//! outside the shed-monotonicity checks).
//!
//! Per (scenario, class) the harness reports offered load, goodput,
//! shed counts, and p50/p99/p99.9 latency (from the coordinator's
//! per-class reservoirs), plus a server-side **per-stage breakdown**
//! (queue wait vs kernel vs egress flush at p50/p99, from the
//! [`RouteStages`](crate::obs::RouteStages) histograms), and writes
//! `rust/BENCH_serve.json` (schema `draco.serve.v1`) next to the
//! hotpath bench dump so the overload envelope is tracked in-repo. In every scenario a trickle of
//! **probe jobs with an already-expired deadline** rides along; a probe
//! that comes back `Ok` means an expired job was executed — the
//! invariant `--smoke` asserts never happens.
//!
//! `--smoke` (wired into CI) runs a short ramp and additionally checks:
//! monotone shedding (the reject rate must not fall as offered load
//! grows), Control-class p99 under overload within 2× its uncontended
//! p99 (plus one batching window of tolerance), and the circuit-breaker
//! cycle (trip on consecutive injected panics → shed → half-open →
//! recover).
//!
//! Three wire-robustness scenarios ride along in every run (and are the
//! whole run under `--faults`, the CI fault smoke): `real-net-multi`
//! (concurrent connections with overlapping request ids, responses
//! checked bitwise against per-connection references), `real-net-faults`
//! (a healthy client beside seeded garbage-injecting, write-tearing,
//! and mid-stream-disconnecting peers), and `net_retry_recovery` (a
//! hint-honouring retry client converging against a saturated route).

use super::batcher::{BackendSpec, Coordinator, JobResult, Route};
use super::qos::{QosClass, QosPolicy, ServeError, SubmitOptions};
use super::registry::DEFAULT_QUANT_FORMAT;
use crate::model::{builtin_robot, Robot};
use crate::quant::QFormat;
use crate::runtime::artifact::ArtifactFn;
use crate::util::bench::Table;
use crate::util::cli::Args;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

/// Shared knobs of one loadgen run (every scenario reuses them).
struct LoadCfg {
    batch: usize,
    window_us: u64,
    delay_us: u64,
    /// Class mix weights, indexed by [`QosClass::index`] (normalized at
    /// sampling time).
    mix: [f64; 3],
    duration: Duration,
    seed: u64,
    policy: QosPolicy,
}

impl LoadCfg {
    /// Deterministic route capacity [tasks/s]: one batch per
    /// (drain window + throttled execution) cycle.
    fn capacity_per_s(&self) -> f64 {
        self.batch as f64 * 1e6 / (self.delay_us + self.window_us) as f64
    }
}

/// Client-side outcome counts and server-side latency percentiles of
/// one class in one scenario.
#[derive(Debug, Clone, Copy, Default)]
struct ClassOutcome {
    offered: u64,
    completed: u64,
    /// Admission rejections plus breaker sheds (both are refused-before-
    /// execution outcomes).
    rejected: u64,
    expired: u64,
    engine_errors: u64,
    /// Wire-client resubmissions after `rejected`/`shed`/`expired`
    /// hints (populated by the retry scenario; 0 elsewhere).
    retries: u64,
    /// Total client-side backoff time across those retries [µs].
    backoff_us: u64,
    p50_us: f64,
    p99_us: f64,
    p999_us: f64,
}

/// Server-side per-stage latency attribution of one scenario: where a
/// request's time went — queue wait vs kernel execution vs egress flush
/// — read from the coordinator's aggregate stage histograms (see
/// [`RouteStages`](crate::obs::RouteStages)) before shutdown. All-zero
/// for the wire-robustness scenarios, which measure other invariants.
#[derive(Debug, Clone, Copy, Default)]
struct StageBreakdown {
    queue_p50_us: f64,
    queue_p99_us: f64,
    kernel_p50_us: f64,
    kernel_p99_us: f64,
    egress_p50_us: f64,
    egress_p99_us: f64,
}

impl StageBreakdown {
    /// Read the unlabelled (all-class, all-route) stage histograms from
    /// `coord`'s metrics registry. Loadgen scenarios run one route per
    /// fresh coordinator, so the aggregates are exactly this scenario's.
    fn capture(coord: &Coordinator) -> StageBreakdown {
        let snap = coord.obs().snapshot();
        let pick = |name: &str| -> (f64, f64) {
            match snap.hists.get(name) {
                Some(h) => (h.percentile(0.50), h.percentile(0.99)),
                None => (0.0, 0.0),
            }
        };
        let (queue_p50_us, queue_p99_us) = pick("stage_queue_us");
        let (kernel_p50_us, kernel_p99_us) = pick("stage_kernel_us");
        let (egress_p50_us, egress_p99_us) = pick("stage_egress_us");
        StageBreakdown {
            queue_p50_us,
            queue_p99_us,
            kernel_p50_us,
            kernel_p99_us,
            egress_p50_us,
            egress_p99_us,
        }
    }
}

/// One scenario: a name, its offered rate, and the per-class outcomes.
struct ScenarioResult {
    name: String,
    offered_per_s: f64,
    elapsed_s: f64,
    classes: [ClassOutcome; 3],
    /// Deadline-0 probe jobs that came back `Ok` — an executed expired
    /// job. Must stay 0.
    probes_executed: u64,
    probes_sent: u64,
    /// Server-side queue/kernel/egress attribution (zeros where the
    /// scenario does not drive a fresh single-route coordinator).
    stages: StageBreakdown,
}

impl ScenarioResult {
    fn total_offered(&self) -> u64 {
        self.classes.iter().map(|c| c.offered).sum()
    }

    fn reject_rate(&self) -> f64 {
        let rejected: u64 = self.classes.iter().map(|c| c.rejected).sum();
        let offered = self.total_offered();
        if offered == 0 {
            0.0
        } else {
            rejected as f64 / offered as f64
        }
    }
}

/// Sample a class from the (unnormalized) mix weights.
fn sample_class(rng: &mut Rng, mix: &[f64; 3]) -> QosClass {
    let total: f64 = mix.iter().sum();
    let u = rng.f64() * total;
    if u < mix[0] {
        QosClass::Control
    } else if u < mix[0] + mix[1] {
        QosClass::Interactive
    } else {
        QosClass::Bulk
    }
}

/// Parse `control:0.2,interactive:0.3,bulk:0.5` into mix weights.
fn parse_mix(s: &str) -> Result<[f64; 3], String> {
    let mut mix = [0.0; 3];
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (name, w) =
            part.split_once(':').ok_or_else(|| format!("bad class weight '{part}' (want name:weight)"))?;
        let class = QosClass::parse(name.trim())
            .ok_or_else(|| format!("unknown class '{name}' (try control|interactive|bulk)"))?;
        mix[class.index()] =
            w.trim().parse().map_err(|_| format!("bad weight '{w}' in '{part}'"))?;
    }
    if mix.iter().sum::<f64>() <= 0.0 {
        return Err("class mix needs at least one positive weight".to_string());
    }
    Ok(mix)
}

/// Busy-wait-assisted sleep until `next_s` seconds after `t0`: sleep for
/// the bulk of the gap, spin the last slice so short inter-arrival gaps
/// (sub-millisecond at high rates) keep their shape.
fn wait_until(t0: Instant, next_s: f64) {
    loop {
        let now = t0.elapsed().as_secs_f64();
        if now >= next_s {
            return;
        }
        let rem = next_s - now;
        if rem > 1e-3 {
            std::thread::sleep(Duration::from_secs_f64(rem - 5e-4));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Run one open-loop scenario at `rate_per_s` against a fresh
/// coordinator serving `spec` — the throttled chaos route for the
/// capacity-pinned overload sweep, or a real engine route (`Native` /
/// `NativeInt`) for the traffic-realism envelope rows.
fn run_scenario(
    robot: &Robot,
    cfg: &LoadCfg,
    name: &str,
    rate_per_s: f64,
    spec: BackendSpec,
) -> ScenarioResult {
    let n = robot.dof();
    let function = match spec.route() {
        Route::Step(f) => f,
        Route::Traj => unreachable!("loadgen drives step routes only"),
    };
    let coord = Coordinator::start_with_policy(vec![spec], n, cfg.window_us, cfg.policy);

    // One clean operand template; every request clones it.
    let ops: Vec<Vec<f32>> = vec![vec![0.1; n], vec![0.0; n], vec![0.0; n]];

    let mut rng = Rng::new(cfg.seed ^ rate_per_s.to_bits());
    let mut pending: Vec<(QosClass, Receiver<JobResult>)> = Vec::new();
    let mut probes: Vec<Receiver<JobResult>> = Vec::new();
    let mut classes = [ClassOutcome::default(); 3];

    let dur_s = cfg.duration.as_secs_f64();
    let t0 = Instant::now();
    let mut next_s = 0.0;
    let mut k = 0u64;
    while next_s < dur_s {
        wait_until(t0, next_s);
        let class = sample_class(&mut rng, &cfg.mix);
        classes[class.index()].offered += 1;
        pending.push((
            class,
            coord.submit_to_opts(&robot.name, function, ops.clone(), SubmitOptions::class(class)),
        ));
        // Ride-along probe with an already-expired deadline: it must
        // come back Expired (or Rejected) — never Ok.
        if k % 24 == 23 {
            probes.push(coord.submit_to_opts(
                &robot.name,
                function,
                ops.clone(),
                SubmitOptions { class: Some(class), deadline_us: Some(0) },
            ));
        }
        k += 1;
        // Exponential inter-arrival gap (Poisson process).
        next_s += -(1.0 - rng.f64()).ln() / rate_per_s;
    }
    let elapsed_s = t0.elapsed().as_secs_f64();

    // Drain every outstanding response (bounded queues ⇒ bounded wait).
    for (class, rx) in pending {
        let out = &mut classes[class.index()];
        match rx.recv() {
            Ok(Ok(_)) => out.completed += 1,
            Ok(Err(ServeError::Rejected { .. })) | Ok(Err(ServeError::Shed { .. })) => {
                out.rejected += 1
            }
            Ok(Err(ServeError::Expired { .. })) => out.expired += 1,
            Ok(Err(_)) | Err(_) => out.engine_errors += 1,
        }
    }
    let mut probes_executed = 0u64;
    let probes_sent = probes.len() as u64;
    for rx in probes {
        if matches!(rx.recv(), Ok(Ok(_))) {
            probes_executed += 1;
        }
    }

    let st = coord.stats();
    for c in QosClass::ALL {
        let cs = st.class(c);
        let out = &mut classes[c.index()];
        out.p50_us = cs.p50_latency_us;
        out.p99_us = cs.p99_latency_us;
        out.p999_us = cs.p999_latency_us;
    }
    let stages = StageBreakdown::capture(&coord);
    coord.shutdown();

    ScenarioResult {
        name: name.to_string(),
        offered_per_s: rate_per_s,
        elapsed_s,
        classes,
        probes_executed,
        probes_sent,
        stages,
    }
}

/// Nearest-rank percentile of a sorted sample (0.0 on empty input).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Terminal-frame kind a reader thread reports back, with its receipt
/// time.
const NET_DONE: u8 = 0;
const NET_REJECTED: u8 = 1;
const NET_EXPIRED: u8 = 2;
const NET_ERR: u8 = 3;

/// The same open-loop Poisson scenario, but over the JSONL wire: a
/// fresh coordinator behind a [`NetServer`](crate::net::NetServer) on
/// an ephemeral port, one client connection, requests as `req` lines,
/// outcomes matched back by id on a reader thread. Unlike the
/// in-process scenarios (whose percentiles come from the coordinator's
/// server-side reservoirs), latencies here are **client-side**: send of
/// the request line to receipt of the terminal frame, so the rows in
/// `BENCH_serve.json` track framing + socket + parse overhead too.
fn run_net_scenario(
    robot: &Robot,
    cfg: &LoadCfg,
    name: &str,
    rate_per_s: f64,
) -> Result<ScenarioResult, String> {
    use crate::net::{frame, Frame, NetClient, NetServer};
    use std::io::Write;
    use std::net::TcpStream;
    use std::sync::Arc;

    let n = robot.dof();
    let spec = BackendSpec::Native {
        robot: robot.clone(),
        function: ArtifactFn::Fd,
        batch: cfg.batch,
        parallel: 1,
        class: QosClass::default(),
    };
    let coord = Arc::new(Coordinator::start_with_policy(vec![spec], n, cfg.window_us, cfg.policy));
    let dims = [(robot.name.clone(), n)].into_iter().collect();
    let server = NetServer::start(
        Arc::clone(&coord),
        dims,
        "127.0.0.1:0",
        None,
        &robot.name,
        cfg.batch,
        cfg.window_us,
    )
    .map_err(|e| format!("bind: {e}"))?;

    let mut stream = TcpStream::connect(server.addr()).map_err(|e| format!("connect: {e}"))?;
    let read_half = stream.try_clone().map_err(|e| e.to_string())?;
    let (term_tx, term_rx) = std::sync::mpsc::channel::<(u64, u8, Instant)>();
    let reader = std::thread::spawn(move || {
        let Ok(mut client) = NetClient::from_stream(read_half) else { return };
        while let Ok(f) = client.read_frame() {
            let at = Instant::now();
            let id = f.id().unwrap_or(u64::MAX);
            let kind = match f {
                Frame::Done { .. } => NET_DONE,
                Frame::Rejected { .. } | Frame::Shed { .. } => NET_REJECTED,
                Frame::Expired { .. } => NET_EXPIRED,
                Frame::Err { .. } => NET_ERR,
                _ => continue,
            };
            if term_tx.send((id, kind, at)).is_err() {
                return;
            }
        }
    });

    let ops: Vec<Vec<f32>> = vec![vec![0.1; n], vec![0.0; n], vec![0.0; n]];
    let mut rng = Rng::new(cfg.seed ^ rate_per_s.to_bits() ^ 0x6e65);
    // Per request id (sequential): class, probe flag, send instant.
    let mut sent: Vec<(QosClass, bool, Instant)> = Vec::new();
    let mut classes = [ClassOutcome::default(); 3];
    let dur_s = cfg.duration.as_secs_f64();
    let t0 = Instant::now();
    let mut next_s = 0.0;
    let mut k = 0u64;
    let send = |line: &str, stream: &mut TcpStream| -> Result<(), String> {
        stream.write_all(line.as_bytes()).map_err(|e| format!("send: {e}"))?;
        stream.write_all(b"\n").map_err(|e| format!("send: {e}"))
    };
    while next_s < dur_s {
        wait_until(t0, next_s);
        let class = sample_class(&mut rng, &cfg.mix);
        classes[class.index()].offered += 1;
        let id = sent.len() as u64;
        let line =
            frame::req_step_line(id, &robot.name, "fd", Some(class.name()), None, &ops);
        sent.push((class, false, Instant::now()));
        send(&line, &mut stream)?;
        if k % 24 == 23 {
            let id = sent.len() as u64;
            let line = frame::req_step_line(
                id,
                &robot.name,
                "fd",
                Some(class.name()),
                Some(0),
                &ops,
            );
            sent.push((class, true, Instant::now()));
            send(&line, &mut stream)?;
        }
        k += 1;
        next_s += -(1.0 - rng.f64()).ln() / rate_per_s;
    }
    let elapsed_s = t0.elapsed().as_secs_f64();

    // Collect one terminal frame per request (bounded wait).
    let mut outcomes: Vec<Option<(u8, Instant)>> = vec![None; sent.len()];
    let mut got = 0usize;
    let deadline = Instant::now() + Duration::from_secs(10);
    while got < sent.len() {
        let left = deadline.saturating_duration_since(Instant::now());
        match term_rx.recv_timeout(left) {
            Ok((id, kind, at)) => {
                if let Some(slot) = outcomes.get_mut(id as usize) {
                    if slot.is_none() {
                        *slot = Some((kind, at));
                        got += 1;
                    }
                }
            }
            Err(_) => break,
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
    let _ = reader.join();
    server.stop();

    let mut probes_sent = 0u64;
    let mut probes_executed = 0u64;
    let mut lat: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for (i, (class, probe, sent_at)) in sent.iter().enumerate() {
        let outcome = outcomes[i];
        if *probe {
            probes_sent += 1;
            if matches!(outcome, Some((NET_DONE, _))) {
                probes_executed += 1;
            }
            continue;
        }
        let out = &mut classes[class.index()];
        match outcome {
            Some((NET_DONE, at)) => {
                out.completed += 1;
                lat[class.index()].push(at.duration_since(*sent_at).as_secs_f64() * 1e6);
            }
            Some((NET_REJECTED, _)) => out.rejected += 1,
            Some((NET_EXPIRED, _)) => out.expired += 1,
            // `err` frames and unresolved ids are both serving bugs on
            // clean traffic; the invariant check flags them.
            Some((_, _)) | None => out.engine_errors += 1,
        }
    }
    for (i, l) in lat.iter_mut().enumerate() {
        l.sort_by(f64::total_cmp);
        classes[i].p50_us = pct(l, 0.50);
        classes[i].p99_us = pct(l, 0.99);
        classes[i].p999_us = pct(l, 0.999);
    }

    Ok(ScenarioResult {
        name: name.to_string(),
        offered_per_s: rate_per_s,
        elapsed_s,
        classes,
        probes_executed,
        probes_sent,
        stages: StageBreakdown::capture(&coord),
    })
}

/// Connections the multi-client and fault scenarios drive concurrently.
const FAULT_CONNS: usize = 4;

/// Per-connection tally a client thread reports back.
#[derive(Debug, Default, Clone)]
struct ConnTally {
    offered: u64,
    completed: u64,
    rejected: u64,
    expired: u64,
    /// `err` frames with id 0 — the server's in-band answers to
    /// injected garbage lines.
    garbage_errs: u64,
    lat_us: Vec<f64>,
}

/// Fold per-connection tallies into the per-class outcome array, with
/// client-side latency percentiles.
fn fold_tallies(tallies: Vec<(QosClass, ConnTally)>) -> [ClassOutcome; 3] {
    let mut classes = [ClassOutcome::default(); 3];
    let mut lat: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for (class, t) in tallies {
        let out = &mut classes[class.index()];
        out.offered += t.offered;
        out.completed += t.completed;
        out.rejected += t.rejected;
        out.expired += t.expired;
        lat[class.index()].extend(t.lat_us);
    }
    for (i, l) in lat.iter_mut().enumerate() {
        l.sort_by(f64::total_cmp);
        classes[i].p50_us = pct(l, 0.50);
        classes[i].p99_us = pct(l, 0.99);
        classes[i].p999_us = pct(l, 0.999);
    }
    classes
}

/// Operand set for connection `c` — each connection's values differ, so
/// each connection's correct payload is distinct and a response bled
/// across connections cannot pass the bitwise check.
fn conn_ops(n: usize, c: usize) -> Vec<Vec<f32>> {
    vec![vec![0.1 + 0.05 * c as f32; n], vec![0.01 * c as f32; n], vec![0.0; n]]
}

/// Read frames until `id`'s terminal frame, folding the outcome into
/// `t`. `err` frames for id 0 (the server's answers to injected garbage
/// lines) are counted, not fatal; an `err` for `id` itself is — these
/// helpers only send well-formed traffic. With `expect`, a `done`
/// payload must match it bitwise (the cross-connection routing check).
/// Returns the payload on `done`, `None` on a structured refusal.
fn read_terminal(
    client: &mut crate::net::NetClient,
    id: u64,
    expect: Option<&[f32]>,
    sent_at: Instant,
    t: &mut ConnTally,
) -> Result<Option<Vec<f32>>, String> {
    use crate::net::Frame;
    let mut payload: Vec<f32> = Vec::new();
    loop {
        match client.read_frame().map_err(|e| format!("read: {e}"))? {
            Frame::Ack { id: got } if got == id => {}
            Frame::Chunk { id: got, data, .. } if got == id => payload.extend(data),
            Frame::Done { id: got, .. } if got == id => {
                if let Some(want) = expect {
                    let same = payload.len() == want.len()
                        && payload.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits());
                    if !same {
                        return Err(format!(
                            "id {id}: payload differs from this connection's reference — \
                             response bled across connections"
                        ));
                    }
                }
                t.completed += 1;
                t.lat_us.push(sent_at.elapsed().as_secs_f64() * 1e6);
                return Ok(Some(payload));
            }
            Frame::Rejected { id: got, .. } | Frame::Shed { id: got, .. } if got == id => {
                t.rejected += 1;
                return Ok(None);
            }
            Frame::Expired { id: got, .. } if got == id => {
                t.expired += 1;
                return Ok(None);
            }
            Frame::Err { id: 0, .. } => t.garbage_errs += 1,
            Frame::Err { id: got, msg } if got == id => {
                return Err(format!("id {id}: err on well-formed traffic: {msg}"))
            }
            _ => {}
        }
    }
}

/// Send one step request on a healthy client and block for its terminal
/// frame (see [`read_terminal`] for outcome handling).
fn drive_step(
    client: &mut crate::net::NetClient,
    id: u64,
    robot: &str,
    class: QosClass,
    ops: &[Vec<f32>],
    expect: Option<&[f32]>,
    t: &mut ConnTally,
) -> Result<Option<Vec<f32>>, String> {
    use crate::net::frame;
    t.offered += 1;
    let sent_at = Instant::now();
    client
        .send_line(&frame::req_step_line(id, robot, "fd", Some(class.name()), None, ops))
        .map_err(|e| format!("send: {e}"))?;
    read_terminal(client, id, expect, sent_at, t)
}

/// Poll every class lane of the served step route until all drain to
/// depth 0 — the no-stuck-batches invariant after clients disconnect.
fn drain_check(coord: &Coordinator, robot: &str) -> Result<(), String> {
    let deadline = Instant::now() + Duration::from_secs(3);
    loop {
        let depth: usize =
            QosClass::ALL.iter().map(|&c| coord.depth(robot, ArtifactFn::Fd, c)).sum();
        if depth == 0 {
            return Ok(());
        }
        if Instant::now() >= deadline {
            return Err(format!("stuck batches: route depth still {depth} after clients left"));
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// `real-net-multi` (bench row `serve_net_multi`): [`FAULT_CONNS`]
/// concurrent client connections drive the same route with deliberately
/// **overlapping request ids** and per-connection operands. Every
/// response must arrive on the connection that asked, bitwise identical
/// to the in-process reference for that connection's operands — the
/// cross-connection id-bleed check — and after the clients leave, every
/// lane must drain to depth 0.
fn run_multi_scenario(robot: &Robot, cfg: &LoadCfg) -> Result<ScenarioResult, String> {
    use crate::net::{NetClient, NetServer};
    use std::sync::Arc;

    let n = robot.dof();
    let spec = BackendSpec::Native {
        robot: robot.clone(),
        function: ArtifactFn::Fd,
        batch: cfg.batch,
        parallel: 1,
        class: QosClass::default(),
    };
    let coord = Arc::new(Coordinator::start_with_policy(vec![spec], n, cfg.window_us, cfg.policy));
    let dims = [(robot.name.clone(), n)].into_iter().collect();
    let server = NetServer::start(
        Arc::clone(&coord),
        dims,
        "127.0.0.1:0",
        None,
        &robot.name,
        cfg.batch,
        cfg.window_us,
    )
    .map_err(|e| format!("bind: {e}"))?;
    let addr = server.addr();

    // In-process reference payload per connection's operand set.
    let mut expected: Vec<Vec<f32>> = Vec::new();
    for c in 0..FAULT_CONNS {
        match coord.submit_to(&robot.name, ArtifactFn::Fd, conn_ops(n, c)).recv() {
            Ok(Ok(v)) => expected.push(v),
            other => return Err(format!("in-process reference for conn {c}: {other:?}")),
        }
    }

    let conn_class = [QosClass::Control, QosClass::Interactive, QosClass::Bulk, QosClass::Bulk];
    let per_conn: u64 = 48;
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..FAULT_CONNS {
        let robot_name = robot.name.clone();
        let want = expected[c].clone();
        let ops = conn_ops(n, c);
        let class = conn_class[c % conn_class.len()];
        threads.push(std::thread::spawn(move || -> Result<(QosClass, ConnTally), String> {
            let mut client = NetClient::connect(addr).map_err(|e| format!("conn {c}: {e}"))?;
            let mut t = ConnTally::default();
            for i in 0..per_conn {
                // Ids overlap across connections by construction; only
                // the payload (distinct per connection) proves routing.
                let got =
                    drive_step(&mut client, 1 + i, &robot_name, class, &ops, Some(&want), &mut t)
                        .map_err(|e| format!("conn {c}: {e}"))?;
                if got.is_none() {
                    return Err(format!("conn {c}: request {} refused on a lightly loaded route", 1 + i));
                }
            }
            Ok((class, t))
        }));
    }
    let mut tallies = Vec::new();
    for th in threads {
        tallies.push(th.join().map_err(|_| "client thread panicked".to_string())??);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    drain_check(&coord, &robot.name)?;
    server.stop();

    Ok(ScenarioResult {
        name: "real-net-multi".to_string(),
        offered_per_s: (FAULT_CONNS as u64 * per_conn) as f64 / elapsed_s,
        elapsed_s,
        classes: fold_tallies(tallies),
        probes_executed: 0,
        probes_sent: 0,
        stages: StageBreakdown::default(),
    })
}

/// `real-net-faults`: [`FAULT_CONNS`] concurrent connections, three of
/// them hostile under seeded [`FaultPlan`](crate::net::FaultPlan)s —
/// every-line garbage injection, every-line torn dribbled writes, and a
/// mid-stream disconnect while a trajectory is still streaming against
/// a full egress queue. Asserts the tentpole invariants: the healthy
/// connection's payloads stay bitwise identical to a fault-free wire
/// pass, every hostile request that reached the wire intact still
/// terminates with its correct payload, garbage is answered in-band
/// (`err`) without dropping anyone, the dead peer leaves no stuck
/// batches, and a fresh client is served immediately afterwards.
fn run_faults_scenario(robot: &Robot, cfg: &LoadCfg) -> Result<ScenarioResult, String> {
    use super::registry::RobotRegistry;
    use crate::net::{frame, FaultPlan, FaultyClient, NetClient, NetServer};
    use std::net::TcpStream;
    use std::sync::Arc;

    let n = robot.dof();
    // Full registry: the disconnect connection needs the traj route.
    let registry = RobotRegistry::from_cli_spec(&robot.name, cfg.batch)?;
    let coord = Arc::new(Coordinator::start_registry(&registry, cfg.window_us));
    let dims = [(robot.name.clone(), n)].into_iter().collect();
    let server = NetServer::start(
        Arc::clone(&coord),
        dims,
        "127.0.0.1:0",
        None,
        &robot.name,
        cfg.batch,
        cfg.window_us,
    )
    .map_err(|e| format!("bind: {e}"))?;
    let addr = server.addr();
    let k: u64 = 24;

    // Fault-free reference pass: the healthy connection's payload, from
    // an undisturbed wire round trip.
    let reference = {
        let mut client = NetClient::connect(addr).map_err(|e| format!("reference: {e}"))?;
        let mut t = ConnTally::default();
        drive_step(&mut client, 1, &robot.name, QosClass::Control, &conn_ops(n, 0), None, &mut t)
            .map_err(|e| format!("reference: {e}"))?
            .ok_or("reference request refused on an idle route")?
    };
    // In-process references for the hostile connections' operand sets.
    let mut expected: Vec<Vec<f32>> = Vec::new();
    for c in 0..FAULT_CONNS {
        match coord.submit_to(&robot.name, ArtifactFn::Fd, conn_ops(n, c)).recv() {
            Ok(Ok(v)) => expected.push(v),
            other => return Err(format!("in-process reference for conn {c}: {other:?}")),
        }
    }

    let t0 = Instant::now();
    let mut threads: Vec<
        std::thread::JoinHandle<Result<(QosClass, ConnTally), String>>,
    > = Vec::new();

    // Connection 0 — healthy control client: every payload must stay
    // bitwise identical to the fault-free reference while the peers
    // misbehave.
    {
        let robot_name = robot.name.clone();
        let want = reference.clone();
        let ops = conn_ops(n, 0);
        threads.push(std::thread::spawn(move || {
            let mut client = NetClient::connect(addr).map_err(|e| format!("healthy: {e}"))?;
            let mut t = ConnTally::default();
            for i in 0..k {
                let got = drive_step(
                    &mut client,
                    1 + i,
                    &robot_name,
                    QosClass::Control,
                    &ops,
                    Some(&want),
                    &mut t,
                )
                .map_err(|e| format!("healthy: {e}"))?;
                if got.is_none() {
                    return Err(format!("healthy: request {} refused on an idle route", 1 + i));
                }
            }
            Ok((QosClass::Control, t))
        }));
    }

    // Connection 1 — garbage injector: one garbage line before every
    // real request. Every garbage line must be answered in-band (`err`
    // id 0) and every real request must still complete correctly.
    {
        let robot_name = robot.name.clone();
        let want = expected[1].clone();
        let ops = conn_ops(n, 1);
        let seed = cfg.seed ^ 0x9a7b;
        threads.push(std::thread::spawn(move || {
            let sock = TcpStream::connect(addr).map_err(|e| format!("garbage: {e}"))?;
            let read_half = sock.try_clone().map_err(|e| format!("garbage: {e}"))?;
            let plan = FaultPlan {
                seed,
                garbage_every: 1.0,
                tear_writes: 0.0,
                fragment_delay_us: 0,
                disconnect_after: 0,
            };
            let mut faulty =
                FaultyClient::from_stream(sock, plan).map_err(|e| format!("garbage: {e}"))?;
            let mut reader =
                NetClient::from_stream(read_half).map_err(|e| format!("garbage: {e}"))?;
            let mut t = ConnTally::default();
            let g = k / 2;
            for i in 0..g {
                t.offered += 1;
                let sent_at = Instant::now();
                faulty
                    .send_line(&frame::req_step_line(
                        1 + i,
                        &robot_name,
                        "fd",
                        Some(QosClass::Interactive.name()),
                        None,
                        &ops,
                    ))
                    .map_err(|e| format!("garbage: send: {e}"))?;
                let got = read_terminal(&mut reader, 1 + i, Some(&want), sent_at, &mut t)
                    .map_err(|e| format!("garbage: {e}"))?;
                if got.is_none() {
                    return Err(format!("garbage: request {} refused on an idle route", 1 + i));
                }
            }
            if t.garbage_errs == 0 {
                return Err("garbage: injected lines produced no err frames".to_string());
            }
            Ok((QosClass::Interactive, t))
        }));
    }

    // Connection 2 — torn writer: every request line dribbled across
    // delayed fragments (driving the server's resumable bounded reads).
    // Each request must still complete with its correct payload.
    {
        let robot_name = robot.name.clone();
        let want = expected[2].clone();
        let ops = conn_ops(n, 2);
        let seed = cfg.seed ^ 0x70a1;
        threads.push(std::thread::spawn(move || {
            let sock = TcpStream::connect(addr).map_err(|e| format!("torn: {e}"))?;
            let read_half = sock.try_clone().map_err(|e| format!("torn: {e}"))?;
            let plan = FaultPlan {
                seed,
                garbage_every: 0.0,
                tear_writes: 1.0,
                fragment_delay_us: 300,
                disconnect_after: 0,
            };
            let mut faulty =
                FaultyClient::from_stream(sock, plan).map_err(|e| format!("torn: {e}"))?;
            let mut reader = NetClient::from_stream(read_half).map_err(|e| format!("torn: {e}"))?;
            let mut t = ConnTally::default();
            let g = k / 2;
            for i in 0..g {
                t.offered += 1;
                let sent_at = Instant::now();
                faulty
                    .send_line(&frame::req_step_line(
                        1 + i,
                        &robot_name,
                        "fd",
                        Some(QosClass::Bulk.name()),
                        None,
                        &ops,
                    ))
                    .map_err(|e| format!("torn: send: {e}"))?;
                let got = read_terminal(&mut reader, 1 + i, Some(&want), sent_at, &mut t)
                    .map_err(|e| format!("torn: {e}"))?;
                if got.is_none() {
                    return Err(format!("torn: request {} refused on an idle route", 1 + i));
                }
            }
            Ok((QosClass::Bulk, t))
        }));
    }

    // Connection 3 — mid-stream disconnect: a long trajectory fills the
    // bounded egress queue (the client reads almost nothing), then the
    // plan disconnects mid-line. The server must cancel production via
    // the dead wire and leave nothing stuck.
    {
        let robot_name = robot.name.clone();
        let ops = conn_ops(n, 3);
        let seed = cfg.seed ^ 0xdeadu64;
        threads.push(std::thread::spawn(move || {
            let sock = TcpStream::connect(addr).map_err(|e| format!("disconnect: {e}"))?;
            let read_half = sock.try_clone().map_err(|e| format!("disconnect: {e}"))?;
            let plan = FaultPlan {
                seed,
                garbage_every: 0.0,
                tear_writes: 0.0,
                fragment_delay_us: 0,
                disconnect_after: 2,
            };
            let mut faulty =
                FaultyClient::from_stream(sock, plan).map_err(|e| format!("disconnect: {e}"))?;
            let mut reader =
                NetClient::from_stream(read_half).map_err(|e| format!("disconnect: {e}"))?;
            let mut t = ConnTally::default();
            // Horizon far deeper than the egress queue so the producer
            // is still streaming when the peer vanishes.
            let h = 4096;
            let q0 = vec![0.1f32; n];
            let qd0 = vec![0.0f32; n];
            let tau = vec![0.05f32; h * n];
            t.offered += 1;
            faulty
                .send_line(&frame::req_traj_line(
                    1,
                    &robot_name,
                    Some(QosClass::Bulk.name()),
                    None,
                    &q0,
                    &qd0,
                    &tau,
                    1e-3,
                ))
                .map_err(|e| format!("disconnect: send: {e}"))?;
            // Let the stream start (ack + a few rows), then vanish.
            for _ in 0..4 {
                reader.read_frame().map_err(|e| format!("disconnect: read: {e}"))?;
            }
            t.offered += 1;
            let sent = faulty
                .send_line(&frame::req_step_line(
                    2,
                    &robot_name,
                    "fd",
                    Some(QosClass::Bulk.name()),
                    None,
                    &ops,
                ))
                .map_err(|e| format!("disconnect: send: {e}"))?;
            if sent {
                return Err("disconnect: fault plan failed to cut the connection".to_string());
            }
            Ok((QosClass::Bulk, t))
        }));
    }

    let mut tallies = Vec::new();
    for th in threads {
        tallies.push(th.join().map_err(|_| "fault thread panicked".to_string())??);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();

    // No stuck batches, and the route still serves a fresh client.
    drain_check(&coord, &robot.name)?;
    {
        let mut probe = NetClient::connect(addr).map_err(|e| format!("post-probe: {e}"))?;
        let mut pt = ConnTally::default();
        let got =
            drive_step(&mut probe, 1, &robot.name, QosClass::Control, &conn_ops(n, 0), None, &mut pt)
                .map_err(|e| format!("post-probe: {e}"))?;
        if got.is_none() {
            return Err("post-probe request refused — route wedged by faulty peers".to_string());
        }
    }
    server.stop();

    let classes = fold_tallies(tallies);
    let offered: u64 = classes.iter().map(|c| c.offered).sum();
    Ok(ScenarioResult {
        name: "real-net-faults".to_string(),
        offered_per_s: offered as f64 / elapsed_s,
        elapsed_s,
        classes,
        probes_executed: 0,
        probes_sent: 0,
        stages: StageBreakdown::default(),
    })
}

/// `net_retry_recovery`: a [`RetryClient`](crate::net::RetryClient)
/// against a deliberately saturated route. The bulk lane is capped low
/// and pre-filled with an in-process flood, so the client's first wire
/// attempts come back `rejected` with live retry hints; the client must
/// back off (hint-aware, jittered) and converge to success on every
/// request as the flood drains — its attempt and backoff totals feed
/// the goodput table's retry columns.
fn run_retry_scenario(robot: &Robot, cfg: &LoadCfg) -> Result<ScenarioResult, String> {
    use crate::net::{NetServer, RetryClient, RetryOutcome, RetryPolicy};
    use std::sync::Arc;

    let n = robot.dof();
    let spec = BackendSpec::Chaos {
        robot: robot.clone(),
        function: ArtifactFn::Fd,
        batch: cfg.batch,
        delay_us: cfg.delay_us,
        class: QosClass::default(),
    };
    let policy = QosPolicy { queue_cap: [8, 8, 8], ..QosPolicy::default() };
    let coord = Arc::new(Coordinator::start_with_policy(vec![spec], n, cfg.window_us, policy));
    let dims = [(robot.name.clone(), n)].into_iter().collect();
    let server = NetServer::start(
        Arc::clone(&coord),
        dims,
        "127.0.0.1:0",
        None,
        &robot.name,
        cfg.batch,
        cfg.window_us,
    )
    .map_err(|e| format!("bind: {e}"))?;

    let retry_policy = RetryPolicy { base_us: 500, budget_us: 3_000_000, ..RetryPolicy::default() };
    let mut client = RetryClient::connect(server.addr(), retry_policy, cfg.seed ^ 0x7e72)
        .map_err(|e| format!("connect: {e}"))?;

    // Saturate the bulk lane (the throttled route holds its depth at
    // the cap for a full batch cycle); the flood's own overflow resolves
    // as rejected immediately, and the receivers are drained at the end.
    let ops = conn_ops(n, 0);
    let flood: Vec<Receiver<JobResult>> = (0..16)
        .map(|_| {
            coord.submit_to_opts(
                &robot.name,
                ArtifactFn::Fd,
                ops.clone(),
                SubmitOptions::class(QosClass::Bulk),
            )
        })
        .collect();

    let reqs: u64 = 6;
    let mut t = ConnTally::default();
    let t0 = Instant::now();
    for i in 0..reqs {
        t.offered += 1;
        let sent_at = Instant::now();
        match client.step(1 + i, &robot.name, "fd", Some(QosClass::Bulk.name()), &ops) {
            Ok(RetryOutcome::Ok(_)) => {
                t.completed += 1;
                t.lat_us.push(sent_at.elapsed().as_secs_f64() * 1e6);
            }
            Ok(RetryOutcome::Exhausted(what)) => {
                return Err(format!("retry budget exhausted on request {}: {what}", 1 + i))
            }
            Ok(RetryOutcome::Err(msg)) => {
                return Err(format!("request {} hit a terminal err: {msg}", 1 + i))
            }
            Err(e) => return Err(format!("transport: {e}")),
        }
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    let stats = client.stats();
    if stats.retries == 0 {
        return Err("saturated route never refused the retry client — scenario inert".to_string());
    }
    for rx in flood {
        let _ = rx.recv();
    }
    drain_check(&coord, &robot.name)?;
    server.stop();

    let mut classes = fold_tallies(vec![(QosClass::Bulk, t)]);
    let b = QosClass::Bulk.index();
    classes[b].retries = stats.retries;
    classes[b].backoff_us = stats.backoff_us;
    Ok(ScenarioResult {
        name: "net_retry_recovery".to_string(),
        offered_per_s: reqs as f64 / elapsed_s,
        elapsed_s,
        classes,
        probes_executed: 0,
        probes_sent: 0,
        stages: StageBreakdown::default(),
    })
}

/// Deterministic circuit-breaker cycle: three injected panics on a
/// batch-of-1 chaos route trip the breaker, the next admission sheds,
/// and after the cooldown a clean half-open probe recovers the route.
fn breaker_cycle(robot: &Robot) -> Result<(), String> {
    let n = robot.dof();
    let spec = BackendSpec::Chaos {
        robot: robot.clone(),
        function: ArtifactFn::Fd,
        batch: 1,
        delay_us: 0,
        class: QosClass::default(),
    };
    let policy = QosPolicy { breaker_trip: 3, breaker_cooldown_us: 50_000, ..QosPolicy::default() };
    let coord = Coordinator::start_with_policy(vec![spec], n, 100, policy);

    let clean: Vec<Vec<f32>> = vec![vec![0.1; n], vec![0.0; n], vec![0.0; n]];
    let mut poison = clean.clone();
    poison[0][0] = f32::INFINITY;

    for i in 0..3 {
        match coord.submit_to(&robot.name, ArtifactFn::Fd, poison.clone()).recv() {
            Ok(Err(ServeError::Engine(_))) => {}
            other => return Err(format!("poison batch {i}: expected Engine error, got {other:?}")),
        }
    }
    match coord.submit_to(&robot.name, ArtifactFn::Fd, clean.clone()).recv() {
        Ok(Err(ServeError::Shed { retry_after_us, .. })) => {
            if retry_after_us == 0 {
                return Err("breaker shed without a retry hint".to_string());
            }
        }
        other => return Err(format!("tripped breaker: expected Shed, got {other:?}")),
    }
    std::thread::sleep(Duration::from_micros(60_000));
    match coord.submit_to(&robot.name, ArtifactFn::Fd, clean).recv() {
        Ok(Ok(_)) => {}
        other => return Err(format!("half-open probe: expected Ok, got {other:?}")),
    }
    let st = coord.stats();
    if st.breaker_trips < 1 {
        return Err("breaker trip was not counted".to_string());
    }
    if st.shed < 1 {
        return Err("breaker shed was not counted".to_string());
    }
    coord.shutdown();
    Ok(())
}

/// Fixed-point format the qint envelope scenario carries, per builtin
/// robot — the formats the scaling analysis proves for each (wider
/// dynamic range needs more integer or fraction bits).
fn qint_format_for(name: &str) -> QFormat {
    match name {
        "atlas" => QFormat::new(12, 14),
        "baxter" => QFormat::new(13, 13),
        _ => DEFAULT_QUANT_FORMAT,
    }
}

/// `draco loadgen`: open-loop Poisson load against a capacity-pinned
/// route, per-class tail-latency / shed report, `rust/BENCH_serve.json`
/// emission. Every run also measures the `real-native-fd` and
/// `real-qint-fd` envelope scenarios (the same arrival process against
/// the unthrottled native f64 and true-integer engines) plus
/// `real-net-fd`: identical arrivals sent as JSONL `req` lines over a
/// real TCP socket with client-side latency accounting, so the dump
/// tracks wire framing + lazy-parse overhead alongside the in-process
/// envelopes.
///
/// * `--robot NAME` — served robot (default `iiwa`).
/// * `--rate R` — offered rate [req/s] of the `overload` scenario
///   (default 2× the pinned capacity).
/// * `--duration-ms D` — per-scenario generation window (default 1500).
/// * `--batch B`, `--window-us W`, `--delay-us U` — capacity pinning:
///   the route serves one batch of `B` per `W + U` µs.
/// * `--classes control:0.2,interactive:0.3,bulk:0.5` — offered mix.
/// * `--ramp` — additionally sweep 1× and 3× capacity scenarios.
/// * `--seed S` — arrival-process seed.
/// * `--smoke` — short CI mode: forces the ramp, then asserts zero
///   expired-executed probes, monotone shedding, the Control-p99
///   overload bound, and the breaker trip/half-open/recover cycle.
///   Exit code 1 on any violation.
/// * `--faults` — fault-suite mode: run only the multi-connection and
///   fault-injection scenarios (`real-net-multi`, `real-net-faults`,
///   `net_retry_recovery`), with every invariant fatal and no bench
///   dump written. This is the CI fault smoke.
///
/// Every full run additionally drives the three wire-robustness
/// scenarios: `real-net-multi` ([`FAULT_CONNS`] concurrent clients,
/// overlapping ids, bitwise routing check — tracked in the bench dump
/// as `serve_net_multi`), `real-net-faults` (seeded garbage / torn
/// writes / mid-stream disconnect peers beside a healthy client), and
/// `net_retry_recovery` (a `RetryClient` converging against a
/// saturated route; its attempt/backoff totals fill the goodput
/// table's retry columns).
pub fn loadgen_cli(args: &Args) -> i32 {
    let smoke = args.flag("smoke");
    let faults_only = args.flag("faults");
    let robot_name = args.opt_or("robot", "iiwa").to_string();
    let robot = match builtin_robot(&robot_name) {
        Some(r) => r,
        None => {
            eprintln!("unknown robot '{robot_name}' (try iiwa|hyq|atlas|baxter)");
            return 2;
        }
    };
    let mix = match parse_mix(args.opt_or("classes", "control:0.2,interactive:0.3,bulk:0.5")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("bad --classes: {e}");
            return 2;
        }
    };
    let cfg = LoadCfg {
        batch: args.opt_usize("batch", 8),
        window_us: args.opt_usize("window-us", 2_000) as u64,
        delay_us: args.opt_usize("delay-us", 20_000) as u64,
        mix,
        duration: Duration::from_millis(args.opt_usize(
            "duration-ms",
            if smoke { 700 } else { 1_500 },
        ) as u64),
        seed: args.opt_usize("seed", 2026) as u64,
        // Tight caps so overload converts to explicit shed responses
        // within a sub-second scenario (the serving default is deeper).
        policy: QosPolicy { queue_cap: [64, 32, 16], ..QosPolicy::default() },
    };
    let capacity = cfg.capacity_per_s();
    let over_rate = args.opt_f64("rate", 2.0 * capacity);
    println!(
        "loadgen: robot {robot_name}, batch {}, window {} µs, delay {} µs → capacity ≈ {:.0} req/s",
        cfg.batch, cfg.window_us, cfg.delay_us, capacity
    );

    // Scenario sweep: the uncontended/overload pair is always measured
    // (their rows are the tracked baseline); --ramp / --smoke add the
    // intermediate and deep-overload points. All of these run on the
    // throttled chaos route so offered-vs-capacity ratios are pinned.
    let chaos_spec = || BackendSpec::Chaos {
        robot: robot.clone(),
        function: ArtifactFn::Fd,
        batch: cfg.batch,
        delay_us: cfg.delay_us,
        class: QosClass::default(),
    };
    let mut plan: Vec<(String, f64, BackendSpec)> = vec![
        ("uncontended".to_string(), 0.5 * capacity, chaos_spec()),
        ("overload".to_string(), over_rate, chaos_spec()),
    ];
    if args.flag("ramp") || smoke {
        plan.push(("ramp-1x".to_string(), capacity, chaos_spec()));
        plan.push(("ramp-3x".to_string(), 3.0 * capacity, chaos_spec()));
    }
    // Traffic realism: the same Poisson front end against the real
    // engines — the native f64 FD route and the true-integer qint FD
    // route — at the chaos route's pinned capacity rate, so
    // `BENCH_serve.json` carries real-engine latency envelopes next to
    // the synthetic overload sweep. `real-*` scenarios keep the
    // expired-probe and clean-traffic invariants but are excluded from
    // the shed-monotonicity checks: their capacity is the engine's own,
    // far above the chaos pin, so they are expected not to shed.
    plan.push((
        "real-native-fd".to_string(),
        capacity,
        BackendSpec::Native {
            robot: robot.clone(),
            function: ArtifactFn::Fd,
            batch: cfg.batch,
            parallel: 1,
            class: QosClass::default(),
        },
    ));
    plan.push((
        "real-qint-fd".to_string(),
        capacity,
        BackendSpec::NativeInt {
            robot: robot.clone(),
            function: ArtifactFn::Fd,
            batch: cfg.batch,
            fmt: qint_format_for(&robot.name),
            parallel: 1,
            class: QosClass::default(),
        },
    ));

    let mut results = Vec::new();
    let mut hard_failures: Vec<String> = Vec::new();
    if !faults_only {
        for (name, rate, spec) in plan {
            println!("\nscenario '{name}': offering {rate:.0} req/s for {:?} …", cfg.duration);
            results.push(run_scenario(&robot, &cfg, &name, rate, spec));
        }
        // Network envelope: the same Poisson arrivals as
        // `real-native-fd`, but as JSONL `req` lines over a real TCP
        // socket, with client-side latency accounting. The `real-`
        // prefix keeps it outside the shed-monotonicity checks, like
        // the other unthrottled-engine rows.
        println!(
            "\nscenario 'real-net-fd': offering {capacity:.0} req/s over the JSONL wire for {:?} …",
            cfg.duration
        );
        match run_net_scenario(&robot, &cfg, "real-net-fd", capacity) {
            Ok(r) => {
                if r.classes.iter().map(|c| c.completed).sum::<u64>() == 0 {
                    hard_failures.push("real-net-fd completed zero requests".to_string());
                }
                results.push(r);
            }
            Err(e) => hard_failures.push(format!("real-net-fd: {e}")),
        }
    }
    // Wire-robustness scenarios: concurrent clients with overlapping
    // ids, the seeded fault suite, and the retry/backoff client. These
    // run in every mode and are the only scenarios in --faults mode.
    let fault_plan: [(&str, fn(&Robot, &LoadCfg) -> Result<ScenarioResult, String>); 3] = [
        ("real-net-multi", run_multi_scenario),
        ("real-net-faults", run_faults_scenario),
        ("net_retry_recovery", run_retry_scenario),
    ];
    for (what, run) in fault_plan {
        println!("\nscenario '{what}' …");
        match run(&robot, &cfg) {
            Ok(r) => results.push(r),
            Err(e) => hard_failures.push(format!("{what}: {e}")),
        }
    }

    let mut table = Table::new(&[
        "scenario", "class", "offered", "ok", "rej", "exp", "retry", "backoff µs", "goodput/s",
        "p50 µs", "p99 µs", "p99.9 µs",
    ]);
    for r in &results {
        for c in QosClass::ALL {
            let o = &r.classes[c.index()];
            table.row(&[
                r.name.clone(),
                c.name().to_string(),
                o.offered.to_string(),
                o.completed.to_string(),
                o.rejected.to_string(),
                o.expired.to_string(),
                o.retries.to_string(),
                o.backoff_us.to_string(),
                format!("{:.0}", o.completed as f64 / r.elapsed_s),
                format!("{:.0}", o.p50_us),
                format!("{:.0}", o.p99_us),
                format!("{:.0}", o.p999_us),
            ]);
        }
    }
    table.print("open-loop serving: offered load vs goodput and tail latency");

    // Per-stage latency attribution: where a completed request's time
    // went, server-side (queue wait vs kernel vs egress flush), from the
    // coordinator's stage histograms. Wire-robustness scenarios carry no
    // breakdown (all-zero rows are skipped).
    let mut stage_table = Table::new(&[
        "scenario", "queue p50", "queue p99", "kernel p50", "kernel p99", "egress p50",
        "egress p99",
    ]);
    for r in &results {
        let s = &r.stages;
        if s.queue_p99_us == 0.0 && s.kernel_p99_us == 0.0 && s.egress_p99_us == 0.0 {
            continue;
        }
        stage_table.row(&[
            r.name.clone(),
            format!("{:.0}", s.queue_p50_us),
            format!("{:.0}", s.queue_p99_us),
            format!("{:.0}", s.kernel_p50_us),
            format!("{:.0}", s.kernel_p99_us),
            format!("{:.0}", s.egress_p50_us),
            format!("{:.0}", s.egress_p99_us),
        ]);
    }
    stage_table.print("per-stage latency attribution [µs]: queue vs kernel vs egress");

    // JSON dump: one row per (scenario, class). "scenario" sorts last
    // among the row keys, so line-oriented extractors can use it as the
    // row terminator (as bench_diff.sh does). Skipped in --faults mode,
    // which runs only a subset of the tracked scenarios.
    if !faults_only {
        let mut rows = Vec::new();
        for r in &results {
            // `real-net-multi` is tracked in the dump under the stable
            // row name `serve_net_multi`; its `real-` display prefix
            // only marks shed-monotonicity exemption.
            let bench_name =
                if r.name == "real-net-multi" { "serve_net_multi" } else { r.name.as_str() };
            for c in QosClass::ALL {
                let o = &r.classes[c.index()];
                rows.push(json::obj(vec![
                    ("scenario", json::s(bench_name)),
                    ("class", json::s(c.name())),
                    ("offered_per_s", json::num(o.offered as f64 / r.elapsed_s)),
                    ("goodput_per_s", json::num(o.completed as f64 / r.elapsed_s)),
                    ("completed", json::num(o.completed as f64)),
                    ("rejected", json::num(o.rejected as f64)),
                    ("expired", json::num(o.expired as f64)),
                    ("retries", json::num(o.retries as f64)),
                    ("backoff_us", json::num(o.backoff_us as f64)),
                    ("p50_us", json::num(o.p50_us)),
                    ("p99_us", json::num(o.p99_us)),
                    ("p999_us", json::num(o.p999_us)),
                    ("queue_p50_us", json::num(r.stages.queue_p50_us)),
                    ("queue_p99_us", json::num(r.stages.queue_p99_us)),
                    ("kernel_p50_us", json::num(r.stages.kernel_p50_us)),
                    ("kernel_p99_us", json::num(r.stages.kernel_p99_us)),
                    ("egress_p50_us", json::num(r.stages.egress_p50_us)),
                    ("egress_p99_us", json::num(r.stages.egress_p99_us)),
                ]));
            }
        }
        let out = json::obj(vec![
            ("schema", json::s("draco.serve.v1")),
            ("smoke", Json::Bool(smoke)),
            ("robot", json::s(&robot.name)),
            ("batch", json::num(cfg.batch as f64)),
            ("window_us", json::num(cfg.window_us as f64)),
            ("delay_us", json::num(cfg.delay_us as f64)),
            ("capacity_per_s", json::num(capacity)),
            ("rows", Json::Arr(rows)),
        ]);
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_serve.json");
        match std::fs::write(path, out.pretty()) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }

    // Invariants. Checked (and fatal) in --smoke and --faults;
    // reported otherwise.
    let mut failures: Vec<String> = Vec::new();
    failures.extend(hard_failures);
    for r in &results {
        if r.probes_executed > 0 {
            failures.push(format!(
                "scenario '{}': {}/{} expired-deadline probes were EXECUTED",
                r.name, r.probes_executed, r.probes_sent
            ));
        }
        // Load scenarios send only clean traffic; an engine error here
        // is a serving bug, not an injected fault.
        let engine: u64 = r.classes.iter().map(|c| c.engine_errors).sum();
        if engine > 0 {
            failures.push(format!(
                "scenario '{}': {engine} engine errors on clean traffic",
                r.name
            ));
        }
    }
    // Monotone shedding: sort by offered rate; the reject rate must not
    // fall as offered load grows (small tolerance for sampling noise),
    // and the deepest overload point must actually shed. Only the
    // capacity-pinned chaos scenarios participate — the `real-*`
    // envelope rows run on unthrottled engines and legitimately absorb
    // the whole offered load, and the `net_*` robustness scenarios
    // measure convergence, not shedding.
    let mut by_rate: Vec<&ScenarioResult> = results
        .iter()
        .filter(|r| !r.name.starts_with("real-") && !r.name.starts_with("net_"))
        .collect();
    by_rate.sort_by(|a, b| a.offered_per_s.total_cmp(&b.offered_per_s));
    for pair in by_rate.windows(2) {
        if pair[1].reject_rate() < pair[0].reject_rate() - 0.05 {
            failures.push(format!(
                "shed rate fell from {:.1}% ('{}' @ {:.0}/s) to {:.1}% ('{}' @ {:.0}/s)",
                pair[0].reject_rate() * 100.0,
                pair[0].name,
                pair[0].offered_per_s,
                pair[1].reject_rate() * 100.0,
                pair[1].name,
                pair[1].offered_per_s,
            ));
        }
    }
    if let Some(deepest) = by_rate.last() {
        if deepest.offered_per_s > 1.5 * capacity && deepest.reject_rate() == 0.0 {
            failures.push(format!(
                "'{}' offered {:.0}/s (≥1.5× capacity) but shed nothing — admission control inert",
                deepest.name, deepest.offered_per_s
            ));
        }
    }
    // Control-class isolation: p99 under overload within 2× the
    // uncontended p99, plus one batching window of scheduling slack.
    let unc = results.iter().find(|r| r.name == "uncontended");
    let over = results.iter().find(|r| r.name == "overload");
    if let (Some(unc), Some(over)) = (unc, over) {
        let ctl = QosClass::Control.index();
        let (u99, o99) = (unc.classes[ctl].p99_us, over.classes[ctl].p99_us);
        let bound = 2.0 * u99 + cfg.window_us as f64;
        println!(
            "control p99: {u99:.0} µs uncontended → {o99:.0} µs under overload (bound {bound:.0})"
        );
        if unc.classes[ctl].completed > 0 && over.classes[ctl].completed > 0 && o99 > bound {
            failures.push(format!(
                "control p99 {o99:.0} µs under overload exceeds 2× uncontended ({u99:.0} µs) + window"
            ));
        }
    }
    if smoke {
        if let Err(e) = breaker_cycle(&robot) {
            failures.push(format!("breaker cycle: {e}"));
        } else {
            println!("breaker cycle: trip → shed → half-open → recover ok");
        }
    }

    if failures.is_empty() {
        if faults_only {
            println!(
                "fault suite green: healthy payloads bitwise-stable beside faulty peers, \
                 no id bleed, no stuck batches, retry client converged"
            );
        } else {
            println!("loadgen invariants hold: no expired job executed, shedding monotone");
        }
        0
    } else {
        for f in &failures {
            eprintln!("LOADGEN VIOLATION: {f}");
        }
        i32::from(smoke || faults_only)
    }
}
