//! Quantized native CPU engine: the fixed-point serving backend.
//!
//! Identical flat-f32 batched interface to [`super::NativeEngine`], but
//! every dynamics evaluation runs through the emulated fixed-point
//! kernels of [`crate::quant::qrbd`] at a per-robot [`QFormat`] — the
//! serving-side realization of the paper's precision-aware co-design:
//! robots whose motion tolerance admits a narrow word width are served
//! with the cheap (DSP-frugal, on FPGA) datapath while other robots in
//! the same process keep full f64 precision.
//!
//! One engine owns one [`QuantScratch`] (the quantized counterpart of
//! the f64 `DynWorkspace`), so quantized batches are allocation-free in
//! the kernels exactly like the native path. Trajectory rollouts compute
//! q̈ with the quantized FD and advance the state with the same
//! semi-implicit update as the f64 integrator — matching the ICMS
//! operating model (fixed-point accelerator in the loop, float state).
//!
//! Like [`super::NativeEngine`], an engine built with parallelism fans
//! batches out across the global [`WorkerPool`] zero-copy
//! ([`WorkerPool::eval_flat_quant`]): pool workers run the identical
//! decode→`QuantScratch`→encode loop at the route's format, so pooled
//! execution is **bitwise identical** to serial
//! (`tests/parallel_quant.rs`). Optional M⁻¹ error compensation
//! ([`MinvCompensation`], paper Fig. 5(d)) is fitted once at engine
//! construction and added to every quantized M⁻¹ before encoding.

use super::artifact::ArtifactFn;
use super::engine::EngineError;
use super::native::{decode, encode, validate_batch, validate_rollout, PAR_MIN_ROWS};
use super::DynamicsEngine;
use crate::dynamics::{BatchKernel, FloatMemo, WorkerPool};
use crate::model::{Robot, State};
use crate::quant::compensate::MinvCompensation;
use crate::quant::{QFormat, QuantScratch};
use crate::sim::integrate::semi_implicit_update;
use crate::spatial::DMat;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Configurations sampled when fitting the per-robot [`MinvCompensation`]
/// offset at engine construction (seeded, deterministic).
const COMP_FIT_SAMPLES: usize = 24;
const COMP_FIT_SEED: u64 = 0xC0;

/// Batched fixed-point CPU executor for one (robot, function, batch,
/// format) route.
pub struct QuantEngine {
    /// The robot this engine serves (shared with pool jobs, so the
    /// workers' `Arc::ptr_eq` cache fast path hits on every batch).
    pub robot: Arc<Robot>,
    /// The RBD function this route evaluates.
    pub function: ArtifactFn,
    /// Maximum tasks per executed batch.
    pub batch: usize,
    /// The fixed-point format every kernel evaluation is rounded to.
    pub fmt: QFormat,
    n: usize,
    /// Max chunks a batch may split into on the global worker pool
    /// (1 = serial execution on the calling thread).
    par_chunks: usize,
    /// Fitted M⁻¹ compensation offset, when requested (Minv routes only).
    comp: Option<MinvCompensation>,
    ws: QuantScratch,
    // Per-task f64 staging buffers (decoded from the flat f32 operands).
    q: Vec<f64>,
    qd: Vec<f64>,
    u: Vec<f64>,
    out_vec: Vec<f64>,
    out_mat: DMat,
    /// Fused-egress staging for `DynAll` tasks (`n² + 2n` values).
    out_all: Vec<f64>,
    /// Robot fingerprint partitioning memo entries.
    robot_fp: u64,
    /// Cross-request kinematics memo for serial `DynAll` batches (keyed
    /// on the post-quantization joint words, so sub-quantum input
    /// perturbations still hit).
    memo: FloatMemo,
    /// Memo `(hits, misses)` accumulated from pooled `DynAll` batches.
    pool_hits: u64,
    pool_misses: u64,
}

impl QuantEngine {
    /// Build a serial engine (and its quantized scratch) for one robot,
    /// function, and fixed-point format.
    pub fn new(robot: Robot, function: ArtifactFn, batch: usize, fmt: QFormat) -> QuantEngine {
        QuantEngine::with_options(robot, function, batch, fmt, 1, false)
    }

    /// As [`QuantEngine::new`], but batches of at least [`PAR_MIN_ROWS`]
    /// rows split into up to `parallel` contiguous chunks on the global
    /// [`WorkerPool`] (`0` = one chunk per pool worker, `1` = serial),
    /// bitwise identical to serial execution.
    pub fn with_parallelism(
        robot: Robot,
        function: ArtifactFn,
        batch: usize,
        fmt: QFormat,
        parallel: usize,
    ) -> QuantEngine {
        QuantEngine::with_options(robot, function, batch, fmt, parallel, false)
    }

    /// Full constructor: parallelism as in [`QuantEngine::with_parallelism`]
    /// plus opt-in M⁻¹ error compensation. When `compensate` is set on an
    /// M⁻¹ route, a per-(robot, format) [`MinvCompensation`] offset is
    /// fitted here (seeded, deterministic) and added to every quantized
    /// M⁻¹ in f64 before encoding; other functions ignore the flag.
    /// Compensated M⁻¹ batches always execute serially — the offset is
    /// applied before the f32 encode, which the pool's in-place handoff
    /// cannot replicate.
    pub fn with_options(
        robot: Robot,
        function: ArtifactFn,
        batch: usize,
        fmt: QFormat,
        parallel: usize,
        compensate: bool,
    ) -> QuantEngine {
        let n = robot.dof();
        assert!(batch > 0, "batch must be positive");
        // Clamp to the pool size, exactly like the native engine:
        // `parallel == 1` never touches (or spawns) the global pool.
        let par_chunks = match parallel {
            1 => 1,
            0 => WorkerPool::global().threads(),
            p => p.min(WorkerPool::global().threads()),
        };
        let comp = if compensate && function == ArtifactFn::Minv {
            let mut rng = Rng::new(COMP_FIT_SEED);
            Some(MinvCompensation::fit(&robot, fmt, COMP_FIT_SAMPLES, &mut rng))
        } else {
            None
        };
        let robot_fp = robot.fingerprint();
        QuantEngine {
            ws: QuantScratch::new(n),
            q: vec![0.0; n],
            qd: vec![0.0; n],
            u: vec![0.0; n],
            out_vec: vec![0.0; n],
            out_mat: DMat::zeros(n, n),
            out_all: vec![0.0; n * n + 2 * n],
            robot_fp,
            memo: FloatMemo::with_default_cap(),
            pool_hits: 0,
            pool_misses: 0,
            robot: Arc::new(robot),
            function,
            batch,
            fmt,
            n,
            par_chunks,
            comp,
        }
    }

    /// Max pool chunks a batch may split into (1 = serial).
    pub fn parallelism(&self) -> usize {
        self.par_chunks
    }

    /// Whether this engine applies the fitted M⁻¹ compensation offset.
    pub fn compensated(&self) -> bool {
        self.comp.is_some()
    }

    /// Robot DOF (the per-operand row length).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Flat output length for a full batch (`batch ·` the per-task size
    /// defined once by [`DynamicsEngine::out_per_task`]).
    pub fn expected_output_len(&self) -> usize {
        self.batch * DynamicsEngine::out_per_task(self)
    }

    /// Execute one batch through the quantized kernels. Same contract as
    /// [`super::NativeEngine::run`]: `arity` flat f32 operands, row-major
    /// (B, N), any B ≤ `batch`.
    ///
    /// With parallelism ([`QuantEngine::with_parallelism`]), batches of ≥
    /// [`PAR_MIN_ROWS`] rows fan out across the global [`WorkerPool`]
    /// zero-copy at this route's format, bitwise identical to the serial
    /// loop below (compensated M⁻¹ stays serial; see
    /// [`QuantEngine::with_options`]).
    pub fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        let n = self.n;
        let b = validate_batch(inputs, self.function.arity(), n, self.batch)?;
        let per_task = DynamicsEngine::out_per_task(self);
        let mut out = vec![0.0f32; b * per_task];
        if self.par_chunks > 1
            && b >= PAR_MIN_ROWS
            && !(self.function == ArtifactFn::Minv && self.comp.is_some())
        {
            let kernel = match self.function {
                ArtifactFn::Rnea => BatchKernel::Rnea,
                ArtifactFn::Fd => BatchKernel::Fd,
                ArtifactFn::Minv => BatchKernel::Minv,
                ArtifactFn::DynAll => BatchKernel::DynAll,
            };
            // M⁻¹ is unary; hand the pool `q` for the unused operands.
            let (qd, u) = match self.function {
                ArtifactFn::Minv => (&inputs[0], &inputs[0]),
                _ => (&inputs[1], &inputs[2]),
            };
            let (hits, misses) = WorkerPool::global().eval_flat_quant(
                &self.robot,
                kernel,
                self.fmt,
                &inputs[0],
                qd,
                u,
                n,
                per_task,
                &mut out,
                self.par_chunks,
            );
            self.pool_hits += hits;
            self.pool_misses += misses;
            return Ok(out);
        }
        for k in 0..b {
            let span = k * n..(k + 1) * n;
            match self.function {
                ArtifactFn::Rnea => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span.clone()], &mut self.u);
                    self.ws.rnea_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        self.fmt,
                        &mut self.out_vec,
                    );
                    encode(&self.out_vec, &mut out[span]);
                }
                ArtifactFn::Fd => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span.clone()], &mut self.u);
                    self.ws.fd_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        self.fmt,
                        &mut self.out_vec,
                    );
                    encode(&self.out_vec, &mut out[span]);
                }
                ArtifactFn::Minv => {
                    decode(&inputs[0][span], &mut self.q);
                    self.ws.minv_into(&self.robot, &self.q, self.fmt, &mut self.out_mat);
                    if let Some(c) = &self.comp {
                        // M̂⁻¹ = quantized M⁻¹ + fitted offset, in f64
                        // before the f32 encode (Fig. 5(d)).
                        for (o, d) in self.out_mat.d.iter_mut().zip(&c.offset.d) {
                            *o += d;
                        }
                    }
                    encode(&self.out_mat.d, &mut out[k * n * n..(k + 1) * n * n]);
                }
                ArtifactFn::DynAll => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span], &mut self.u);
                    self.ws.dyn_all_memo_into(
                        &self.robot,
                        self.robot_fp,
                        &self.q,
                        &self.qd,
                        &self.u,
                        self.fmt,
                        &mut self.memo,
                        &mut self.out_all,
                    );
                    encode(&self.out_all, &mut out[k * per_task..(k + 1) * per_task]);
                }
            }
        }
        Ok(out)
    }

    /// Unroll one trajectory request: q̈ from the quantized FD each step,
    /// state advanced with the same semi-implicit update as the f64
    /// integrator. Response layout matches
    /// [`super::NativeEngine::rollout`]: `2·H·N` f32 — H q-rows then H
    /// q̇-rows.
    pub fn rollout(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
    ) -> Result<Vec<f32>, EngineError> {
        let n = self.n;
        let h = validate_rollout(q0, qd0, tau, dt, n)?;
        let mut out = vec![0.0f32; 2 * h * n];
        let mut t = 0usize;
        self.rollout_stream(q0, qd0, tau, dt, &mut |row| {
            out[t * n..(t + 1) * n].copy_from_slice(&row[..n]);
            out[(h + t) * n..(h + t + 1) * n].copy_from_slice(&row[n..]);
            t += 1;
            true
        })?;
        Ok(out)
    }

    /// Streaming rollout on the quantized lane — per-step `q_t ‖ q̇_t`
    /// emission with the same contract as
    /// [`super::NativeEngine::rollout_stream`].
    pub fn rollout_stream(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
        emit: &mut dyn FnMut(&[f32]) -> bool,
    ) -> Result<usize, EngineError> {
        let n = self.n;
        let h = validate_rollout(q0, qd0, tau, dt, n)?;
        decode(q0, &mut self.q);
        decode(qd0, &mut self.qd);
        let mut state =
            State { q: std::mem::take(&mut self.q), qd: std::mem::take(&mut self.qd) };
        let mut row = vec![0.0f32; 2 * n];
        let mut emitted = h;
        for t in 0..h {
            decode(&tau[t * n..(t + 1) * n], &mut self.u);
            self.ws.fd_into(
                &self.robot,
                &state.q,
                &state.qd,
                &self.u,
                self.fmt,
                &mut self.out_vec,
            );
            semi_implicit_update(&mut state, &self.out_vec, dt);
            encode(&state.q, &mut row[..n]);
            encode(&state.qd, &mut row[n..]);
            if !emit(&row) {
                emitted = t + 1;
                break;
            }
        }
        self.q = state.q;
        self.qd = state.qd;
        Ok(emitted)
    }
}

impl DynamicsEngine for QuantEngine {
    fn robot(&self) -> &Robot {
        &self.robot
    }
    fn function(&self) -> ArtifactFn {
        self.function
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn n(&self) -> usize {
        self.n
    }
    fn memo_counters(&self) -> (u64, u64) {
        let (h, m) = self.memo.counters();
        (h + self.pool_hits, m + self.pool_misses)
    }
    fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        QuantEngine::run(self, inputs)
    }
    fn rollout(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
    ) -> Result<Vec<f32>, EngineError> {
        QuantEngine::rollout(self, q0, qd0, tau, dt)
    }
    fn rollout_stream(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
        emit: &mut dyn FnMut(&[f32]) -> bool,
    ) -> Result<usize, EngineError> {
        QuantEngine::rollout_stream(self, q0, qd0, tau, dt, emit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{builtin_robot, State};
    use crate::quant::qrbd::{quant_fd, quant_minv, quant_rnea};
    use crate::util::rng::Rng;

    fn f32_round(v: &[f64]) -> Vec<f64> {
        v.iter().map(|&x| x as f32 as f64).collect()
    }

    #[test]
    fn quant_engine_matches_allocating_kernels() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let fmt = QFormat::new(12, 14);
        let b = 5;
        let mut rng = Rng::new(710);
        let mut q = Vec::new();
        let mut qd = Vec::new();
        let mut u = Vec::new();
        let mut cases = Vec::new();
        for _ in 0..b {
            let s = State::random(&robot, &mut rng);
            let uu = rng.vec_range(n, -6.0, 6.0);
            q.extend(s.q.iter().map(|&x| x as f32));
            qd.extend(s.qd.iter().map(|&x| x as f32));
            u.extend(uu.iter().map(|&x| x as f32));
            cases.push((s, uu));
        }
        let inputs = vec![q, qd, u];
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv] {
            let mut eng = QuantEngine::new(robot.clone(), function, b, fmt);
            let ins = match function {
                ArtifactFn::Minv => inputs[..1].to_vec(),
                _ => inputs.clone(),
            };
            let out = eng.run(&ins).expect("run");
            for (k, (s, uu)) in cases.iter().enumerate() {
                let qr = f32_round(&s.q);
                let qdr = f32_round(&s.qd);
                let ur = f32_round(uu);
                match function {
                    ArtifactFn::Rnea => {
                        let want = quant_rnea(&robot, &qr, &qdr, &ur, fmt);
                        for i in 0..n {
                            assert_eq!(out[k * n + i], want[i] as f32, "rnea task {k} joint {i}");
                        }
                    }
                    ArtifactFn::Fd => {
                        let want = quant_fd(&robot, &qr, &qdr, &ur, fmt);
                        for i in 0..n {
                            assert_eq!(out[k * n + i], want[i] as f32, "fd task {k} joint {i}");
                        }
                    }
                    ArtifactFn::Minv => {
                        let want = quant_minv(&robot, &qr, fmt);
                        for i in 0..n {
                            for j in 0..n {
                                assert_eq!(
                                    out[k * n * n + i * n + j],
                                    want[(i, j)] as f32,
                                    "minv task {k} [{i}][{j}]"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// The `+comp` registration flag: a compensated M⁻¹ route must serve
    /// strictly smaller error against the exact f64 M⁻¹ than the same
    /// route uncompensated (the paper's Fig. 5(d) correction), and
    /// non-Minv routes must ignore the flag entirely.
    #[test]
    fn compensated_minv_route_reduces_error() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let fmt = QFormat::new(10, 8); // coarse: visible reciprocal error
        let b = 8;
        let mut rng = Rng::new(712);
        let mut q = Vec::with_capacity(b * n);
        let mut states = Vec::with_capacity(b);
        for _ in 0..b {
            let s = State::random(&robot, &mut rng);
            q.extend(s.q.iter().map(|&x| x as f32));
            states.push(s);
        }
        let inputs = vec![q];
        let mut plain = QuantEngine::new(robot.clone(), ArtifactFn::Minv, b, fmt);
        let mut comp = QuantEngine::with_options(robot.clone(), ArtifactFn::Minv, b, fmt, 1, true);
        assert!(!plain.compensated());
        assert!(comp.compensated());
        let out_p = plain.run(&inputs).expect("plain run");
        let out_c = comp.run(&inputs).expect("compensated run");
        let (mut err_p, mut err_c) = (0.0f64, 0.0f64);
        for (k, s) in states.iter().enumerate() {
            let qr: Vec<f64> = s.q.iter().map(|&x| x as f32 as f64).collect();
            let exact = crate::dynamics::minv(&robot, &qr);
            for i in 0..n {
                for j in 0..n {
                    let e = exact[(i, j)];
                    err_p += (out_p[k * n * n + i * n + j] as f64 - e).powi(2);
                    err_c += (out_c[k * n * n + i * n + j] as f64 - e).powi(2);
                }
            }
        }
        assert!(
            err_c < err_p,
            "compensation must reduce aggregate M⁻¹ error: {} vs {}",
            err_c.sqrt(),
            err_p.sqrt()
        );
        // Non-Minv routes never fit an offset.
        let rnea_comp =
            QuantEngine::with_options(robot.clone(), ArtifactFn::Rnea, b, fmt, 1, true);
        assert!(!rnea_comp.compensated());
    }

    /// The fused DynAll route on the rounded lane: serial rows match the
    /// memo-less fused kernel bitwise, and a repeat batch with
    /// sub-quantum input noise still hits the memo (keys are the
    /// post-quantization words) while reproducing identical output.
    #[test]
    fn quant_engine_serves_dyn_all_with_quantized_memo_keys() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let fmt = QFormat::new(12, 12);
        let b = 4;
        let per = n * n + 2 * n;
        let mut rng = Rng::new(713);
        let (mut q, mut qd, mut u) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..b {
            let s = State::random(&robot, &mut rng);
            q.extend(s.q.iter().map(|&x| x as f32));
            qd.extend(s.qd.iter().map(|&x| x as f32));
            u.extend(rng.vec_range(n, -6.0, 6.0).iter().map(|&x| x as f32));
        }
        let inputs = vec![q, qd, u];
        let mut eng = QuantEngine::new(robot.clone(), ArtifactFn::DynAll, b, fmt);
        assert_eq!(crate::runtime::DynamicsEngine::out_per_task(&eng), per);
        let out = eng.run(&inputs).expect("run");
        // Reference: the memo-less fused kernel on the decoded rows.
        let mut ws = QuantScratch::new(n);
        let (mut qr, mut qdr, mut ur) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let mut want = vec![0.0f64; per];
        for k in 0..b {
            for i in 0..n {
                qr[i] = inputs[0][k * n + i] as f64;
                qdr[i] = inputs[1][k * n + i] as f64;
                ur[i] = inputs[2][k * n + i] as f64;
            }
            ws.dyn_all_into(&robot, &qr, &qdr, &ur, fmt, &mut want);
            for (i, w) in want.iter().enumerate() {
                assert_eq!(out[k * per + i], *w as f32, "row {k} value {i}");
            }
        }
        assert_eq!(crate::runtime::DynamicsEngine::memo_counters(&eng), (0, b as u64));
        // Quarter-step noise on q: quantizes to the same words, so the
        // whole batch hits the memo. τ is NOT memo-keyed (it only enters
        // the post-memo fold), so keep it identical for a bitwise match.
        let step = fmt.step() as f32;
        let mut noisy = inputs.clone();
        for x in noisy[0].iter_mut() {
            *x = fmt.q(*x as f64) as f32 + 0.25 * step;
        }
        let again = eng.run(&noisy).expect("warm run");
        assert_eq!(again, out, "sub-quantum noise must replay the cached sweep bitwise");
        assert_eq!(crate::runtime::DynamicsEngine::memo_counters(&eng), (b as u64, b as u64));
    }

    #[test]
    fn quant_engine_validates_like_native() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let fmt = QFormat::new(12, 12);
        let mut eng = QuantEngine::new(robot, ArtifactFn::Rnea, 4, fmt);
        assert!(eng.run(&[vec![0.0; 28]]).is_err());
        assert!(eng.run(&[vec![0.0; 10], vec![0.0; 10], vec![0.0; 10]]).is_err());
        assert!(eng
            .rollout(&vec![0.0; n], &vec![0.0; n], &vec![0.0; n], -1.0)
            .is_err());
    }

    #[test]
    fn quant_rollout_stays_finite_and_tracks_f64_at_high_precision() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut rng = Rng::new(711);
        let s0 = State::random(&robot, &mut rng);
        let q0: Vec<f32> = s0.q.iter().map(|&x| x as f32).collect();
        let qd0: Vec<f32> = s0.qd.iter().map(|&x| x as f32).collect();
        let h = 8;
        let tau: Vec<f32> = rng.vec_range(h * n, -2.0, 2.0).iter().map(|&x| x as f32).collect();
        let dt = 1e-3;

        // Fine format: the quantized rollout must track the f64 one.
        let fine = QFormat::new(16, 32);
        let mut qeng = QuantEngine::new(robot.clone(), ArtifactFn::Fd, 4, fine);
        let mut neng = super::super::NativeEngine::new(robot.clone(), ArtifactFn::Fd, 4);
        let got = qeng.rollout(&q0, &qd0, &tau, dt).expect("quant rollout");
        let want = neng.rollout(&q0, &qd0, &tau, dt).expect("native rollout");
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(a.is_finite(), "quant rollout produced non-finite at {i}");
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                "rollout sample {i}: {a} vs {b}"
            );
        }
    }
}
