//! True-integer fixed-point CPU engine: the `qint` serving backend.
//!
//! Same flat-f32 batched interface as [`super::NativeEngine`] /
//! [`super::QuantEngine`], but every evaluation runs the `i64` lane of
//! [`crate::quant::qint`]: constants scaled once on ingest, integer
//! multiply + shift-renormalize inner loops, one dequantization on
//! egress. FD and M⁻¹ run the **division-deferring** sweeps under the
//! [`ShiftSchedule`] proved at engine construction by the fixed-point
//! scaling analysis ([`crate::quant::scaling`]); RNEA runs the plain
//! integer sweeps (no divider on that path).
//!
//! Construction is fallible by design: [`QIntEngine::new`] returns the
//! scaling analysis' [`crate::quant::scaling::OverflowWitness`] (or the
//! word-width cap) as an [`EngineError`] instead of silently degrading
//! to the rounded-f64 lane — an explicit `qint` registration either
//! serves integer kernels or fails naming the overflowing stage.
//!
//! With parallelism, batches fan out across the global [`WorkerPool`]
//! zero-copy ([`WorkerPool::eval_flat_int`]); the engine's schedule
//! travels with each job (shared `Arc`), so pooled execution is
//! **bitwise identical** to serial (`tests/parallel_qint.rs`).
//! Trajectory rollouts integrate q̈ from the deferred integer FD with
//! the same semi-implicit update as the f64 integrator — integer
//! accelerator in the loop, float state, matching the ICMS operating
//! model.

use super::artifact::ArtifactFn;
use super::engine::EngineError;
use super::native::{decode, encode, validate_batch, validate_rollout, PAR_MIN_ROWS};
use super::DynamicsEngine;
use crate::dynamics::{BatchKernel, IntMemo, WorkerPool};
use crate::model::{Robot, State};
use crate::quant::scaling::{self, ShiftSchedule};
use crate::quant::{QFormat, QuantIntScratch};
use crate::sim::integrate::semi_implicit_update;
use crate::spatial::DMat;
use std::sync::Arc;

/// Batched integer fixed-point executor for one (robot, function,
/// batch, format) route.
pub struct QIntEngine {
    /// The robot this engine serves (shared with pool jobs, so the
    /// workers' `Arc::ptr_eq` cache fast path hits on every batch).
    pub robot: Arc<Robot>,
    /// The RBD function this route evaluates.
    pub function: ArtifactFn,
    /// Maximum tasks per executed batch.
    pub batch: usize,
    /// The fixed-point format the integer lane carries.
    pub fmt: QFormat,
    /// The shift schedule proved at construction (shared with pool
    /// jobs so pooled sweeps hold with identical per-joint shifts).
    sched: Arc<ShiftSchedule>,
    n: usize,
    /// Max chunks a batch may split into on the global worker pool
    /// (1 = serial execution on the calling thread).
    par_chunks: usize,
    ws: QuantIntScratch,
    // Per-task f64 staging buffers (decoded from the flat f32 operands).
    q: Vec<f64>,
    qd: Vec<f64>,
    u: Vec<f64>,
    out_vec: Vec<f64>,
    out_mat: DMat,
    /// Fused-egress staging for `DynAll` tasks (`n² + 2n` values).
    out_all: Vec<f64>,
    /// Cross-request kinematics memo for serial `DynAll` batches (keyed
    /// on the ingested fixed-point joint words; the kernel derives the
    /// robot fingerprint from its schedule check).
    memo: IntMemo,
    /// Memo `(hits, misses)` accumulated from pooled `DynAll` batches.
    pool_hits: u64,
    pool_misses: u64,
}

impl QIntEngine {
    /// Build a serial engine for one robot, function, and format. Runs
    /// the fixed-point scaling analysis; `Err` carries the word-width
    /// cap or the overflow witness naming the rejecting stage.
    pub fn new(
        robot: Robot,
        function: ArtifactFn,
        batch: usize,
        fmt: QFormat,
    ) -> Result<QIntEngine, EngineError> {
        QIntEngine::with_parallelism(robot, function, batch, fmt, 1)
    }

    /// As [`QIntEngine::new`], but batches of at least [`PAR_MIN_ROWS`]
    /// rows split into up to `parallel` contiguous chunks on the global
    /// [`WorkerPool`] (`0` = one chunk per pool worker, `1` = serial),
    /// bitwise identical to serial execution.
    pub fn with_parallelism(
        robot: Robot,
        function: ArtifactFn,
        batch: usize,
        fmt: QFormat,
        parallel: usize,
    ) -> Result<QIntEngine, EngineError> {
        let n = robot.dof();
        assert!(batch > 0, "batch must be positive");
        // Memoized per (robot fingerprint, format): the registry's four
        // routes share one analysis run instead of recomputing it.
        let sched = scaling::validate_int_backend(&robot, fmt).map_err(EngineError)?;
        let par_chunks = match parallel {
            1 => 1,
            0 => WorkerPool::global().threads(),
            p => p.min(WorkerPool::global().threads()),
        };
        Ok(QIntEngine {
            ws: QuantIntScratch::new(n),
            q: vec![0.0; n],
            qd: vec![0.0; n],
            u: vec![0.0; n],
            out_vec: vec![0.0; n],
            out_mat: DMat::zeros(n, n),
            out_all: vec![0.0; n * n + 2 * n],
            memo: IntMemo::with_default_cap(),
            pool_hits: 0,
            pool_misses: 0,
            robot: Arc::new(robot),
            function,
            batch,
            fmt,
            sched,
            n,
            par_chunks,
        })
    }

    /// Max pool chunks a batch may split into (1 = serial).
    pub fn parallelism(&self) -> usize {
        self.par_chunks
    }

    /// The shift schedule this engine's deferred sweeps run under.
    pub fn schedule(&self) -> &ShiftSchedule {
        &self.sched
    }

    /// Robot DOF (the per-operand row length).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Flat output length for a full batch (`batch ·` the per-task size
    /// defined once by [`DynamicsEngine::out_per_task`]).
    pub fn expected_output_len(&self) -> usize {
        self.batch * DynamicsEngine::out_per_task(self)
    }

    /// Execute one batch through the integer kernels. Same contract as
    /// [`super::NativeEngine::run`]: `arity` flat f32 operands,
    /// row-major (B, N), any B ≤ `batch`.
    pub fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        let n = self.n;
        let b = validate_batch(inputs, self.function.arity(), n, self.batch)?;
        let per_task = DynamicsEngine::out_per_task(self);
        let mut out = vec![0.0f32; b * per_task];
        if self.par_chunks > 1 && b >= PAR_MIN_ROWS {
            let kernel = match self.function {
                ArtifactFn::Rnea => BatchKernel::Rnea,
                ArtifactFn::Fd => BatchKernel::Fd,
                ArtifactFn::Minv => BatchKernel::Minv,
                ArtifactFn::DynAll => BatchKernel::DynAll,
            };
            // M⁻¹ is unary; hand the pool `q` for the unused operands.
            let (qd, u) = match self.function {
                ArtifactFn::Minv => (&inputs[0], &inputs[0]),
                _ => (&inputs[1], &inputs[2]),
            };
            let (hits, misses) = WorkerPool::global().eval_flat_int(
                &self.robot,
                kernel,
                self.fmt,
                &self.sched,
                &inputs[0],
                qd,
                u,
                n,
                per_task,
                &mut out,
                self.par_chunks,
            );
            self.pool_hits += hits;
            self.pool_misses += misses;
            return Ok(out);
        }
        for k in 0..b {
            let span = k * n..(k + 1) * n;
            match self.function {
                ArtifactFn::Rnea => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span.clone()], &mut self.u);
                    self.ws.rnea_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        self.fmt,
                        &mut self.out_vec,
                    );
                    encode(&self.out_vec, &mut out[span]);
                }
                ArtifactFn::Fd => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span.clone()], &mut self.u);
                    self.ws.fd_dd_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        &self.sched,
                        &mut self.out_vec,
                    );
                    encode(&self.out_vec, &mut out[span]);
                }
                ArtifactFn::Minv => {
                    decode(&inputs[0][span], &mut self.q);
                    self.ws.minv_dd_into(&self.robot, &self.q, &self.sched, &mut self.out_mat);
                    encode(&self.out_mat.d, &mut out[k * n * n..(k + 1) * n * n]);
                }
                ArtifactFn::DynAll => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span], &mut self.u);
                    self.ws.dyn_all_dd_memo_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        &self.sched,
                        &mut self.memo,
                        &mut self.out_all,
                    );
                    encode(&self.out_all, &mut out[k * per_task..(k + 1) * per_task]);
                }
            }
        }
        Ok(out)
    }

    /// Unroll one trajectory request: q̈ from the deferred integer FD
    /// each step, state advanced with the same semi-implicit update as
    /// the f64 integrator. Response layout matches
    /// [`super::NativeEngine::rollout`]: `2·H·N` f32 — H q-rows then H
    /// q̇-rows.
    pub fn rollout(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
    ) -> Result<Vec<f32>, EngineError> {
        let n = self.n;
        let h = validate_rollout(q0, qd0, tau, dt, n)?;
        let mut out = vec![0.0f32; 2 * h * n];
        let mut t = 0usize;
        self.rollout_stream(q0, qd0, tau, dt, &mut |row| {
            out[t * n..(t + 1) * n].copy_from_slice(&row[..n]);
            out[(h + t) * n..(h + t + 1) * n].copy_from_slice(&row[n..]);
            t += 1;
            true
        })?;
        Ok(out)
    }

    /// Streaming rollout on the integer lane — per-step `q_t ‖ q̇_t`
    /// emission with the same contract as
    /// [`super::NativeEngine::rollout_stream`].
    pub fn rollout_stream(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
        emit: &mut dyn FnMut(&[f32]) -> bool,
    ) -> Result<usize, EngineError> {
        let n = self.n;
        let h = validate_rollout(q0, qd0, tau, dt, n)?;
        decode(q0, &mut self.q);
        decode(qd0, &mut self.qd);
        let mut state =
            State { q: std::mem::take(&mut self.q), qd: std::mem::take(&mut self.qd) };
        let mut row = vec![0.0f32; 2 * n];
        let mut emitted = h;
        for t in 0..h {
            decode(&tau[t * n..(t + 1) * n], &mut self.u);
            self.ws.fd_dd_into(
                &self.robot,
                &state.q,
                &state.qd,
                &self.u,
                &self.sched,
                &mut self.out_vec,
            );
            semi_implicit_update(&mut state, &self.out_vec, dt);
            encode(&state.q, &mut row[..n]);
            encode(&state.qd, &mut row[n..]);
            if !emit(&row) {
                emitted = t + 1;
                break;
            }
        }
        self.q = state.q;
        self.qd = state.qd;
        Ok(emitted)
    }
}

impl DynamicsEngine for QIntEngine {
    fn robot(&self) -> &Robot {
        &self.robot
    }
    fn function(&self) -> ArtifactFn {
        self.function
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn n(&self) -> usize {
        self.n
    }
    fn memo_counters(&self) -> (u64, u64) {
        let (h, m) = self.memo.counters();
        (h + self.pool_hits, m + self.pool_misses)
    }
    fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        QIntEngine::run(self, inputs)
    }
    fn rollout(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
    ) -> Result<Vec<f32>, EngineError> {
        QIntEngine::rollout(self, q0, qd0, tau, dt)
    }
    fn rollout_stream(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
        emit: &mut dyn FnMut(&[f32]) -> bool,
    ) -> Result<usize, EngineError> {
        QIntEngine::rollout_stream(self, q0, qd0, tau, dt, emit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin_robot;
    use crate::quant::qint::{quant_fd_dd_i64, quant_minv_dd_i64, quant_rnea_i64};
    use crate::util::rng::Rng;

    fn f32_round(v: &[f64]) -> Vec<f64> {
        v.iter().map(|&x| x as f32 as f64).collect()
    }

    #[test]
    fn qint_engine_matches_allocating_kernels() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let fmt = QFormat::new(12, 14);
        let b = 5;
        let mut rng = Rng::new(720);
        let mut q = Vec::new();
        let mut qd = Vec::new();
        let mut u = Vec::new();
        let mut cases = Vec::new();
        for _ in 0..b {
            let s = State::random(&robot, &mut rng);
            let uu = rng.vec_range(n, -6.0, 6.0);
            q.extend(s.q.iter().map(|&x| x as f32));
            qd.extend(s.qd.iter().map(|&x| x as f32));
            u.extend(uu.iter().map(|&x| x as f32));
            cases.push((s, uu));
        }
        let inputs = vec![q, qd, u];
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv] {
            let mut eng = QIntEngine::new(robot.clone(), function, b, fmt).expect("accepted");
            let sched = eng.schedule().clone();
            let ins = match function {
                ArtifactFn::Minv => inputs[..1].to_vec(),
                _ => inputs.clone(),
            };
            let out = eng.run(&ins).expect("run");
            for (k, (s, uu)) in cases.iter().enumerate() {
                let qr = f32_round(&s.q);
                let qdr = f32_round(&s.qd);
                let ur = f32_round(uu);
                match function {
                    ArtifactFn::Rnea => {
                        let want = quant_rnea_i64(&robot, &qr, &qdr, &ur, fmt);
                        for i in 0..n {
                            assert_eq!(out[k * n + i], want[i] as f32, "rnea task {k} joint {i}");
                        }
                    }
                    ArtifactFn::Fd => {
                        let want = quant_fd_dd_i64(&robot, &qr, &qdr, &ur, &sched);
                        for i in 0..n {
                            assert_eq!(out[k * n + i], want[i] as f32, "fd task {k} joint {i}");
                        }
                    }
                    ArtifactFn::Minv => {
                        let want = quant_minv_dd_i64(&robot, &qr, &sched);
                        for i in 0..n {
                            for j in 0..n {
                                assert_eq!(
                                    out[k * n * n + i * n + j],
                                    want[(i, j)] as f32,
                                    "minv task {k} [{i}][{j}]"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// Construction surfaces the analysis verdict instead of falling
    /// back: wide words name the cap, range rejections name the stage.
    #[test]
    fn constructor_rejects_with_witness() {
        let baxter = builtin_robot("baxter").unwrap();
        let err = QIntEngine::new(baxter, ArtifactFn::Fd, 8, QFormat::new(12, 12))
            .err()
            .expect("baxter@12.12 must reject");
        assert!(err.0.contains("minv.Dinv"), "witness not surfaced: {}", err.0);
        let iiwa = builtin_robot("iiwa").unwrap();
        let err = QIntEngine::new(iiwa.clone(), ArtifactFn::Fd, 8, QFormat::new(16, 16))
            .err()
            .expect("32-bit words must reject");
        assert!(err.0.contains("26"), "width cap not named: {}", err.0);
        QIntEngine::new(iiwa, ArtifactFn::Fd, 8, QFormat::new(12, 12)).expect("iiwa fits");
    }

    /// The fused DynAll route on the integer lane: serial rows match the
    /// memo-less fused kernel bitwise, and a repeated batch answers from
    /// the memo with identical output.
    #[test]
    fn qint_engine_serves_dyn_all_with_memo() {
        use crate::quant::qint::quant_dyn_all_dd_i64;
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let fmt = QFormat::new(12, 12);
        let b = 4;
        let per = n * n + 2 * n;
        let mut rng = Rng::new(722);
        let (mut q, mut qd, mut u) = (Vec::new(), Vec::new(), Vec::new());
        let mut cases = Vec::new();
        for _ in 0..b {
            let s = State::random(&robot, &mut rng);
            let uu = rng.vec_range(n, -6.0, 6.0);
            q.extend(s.q.iter().map(|&x| x as f32));
            qd.extend(s.qd.iter().map(|&x| x as f32));
            u.extend(uu.iter().map(|&x| x as f32));
            cases.push((s, uu));
        }
        let inputs = vec![q, qd, u];
        let mut eng = QIntEngine::new(robot.clone(), ArtifactFn::DynAll, b, fmt).expect("engine");
        let sched = eng.schedule().clone();
        assert_eq!(DynamicsEngine::out_per_task(&eng), per);
        let out = eng.run(&inputs).expect("run");
        for (k, (s, uu)) in cases.iter().enumerate() {
            let want =
                quant_dyn_all_dd_i64(&robot, &f32_round(&s.q), &f32_round(&s.qd), &f32_round(uu), &sched);
            for (i, w) in want.iter().enumerate() {
                assert_eq!(out[k * per + i], *w as f32, "row {k} value {i}");
            }
        }
        assert_eq!(DynamicsEngine::memo_counters(&eng), (0, b as u64));
        let again = eng.run(&inputs).expect("warm run");
        assert_eq!(again, out, "memo hits must replay the sweep bitwise");
        assert_eq!(DynamicsEngine::memo_counters(&eng), (b as u64, b as u64));
    }

    #[test]
    fn qint_engine_validates_like_native() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut eng =
            QIntEngine::new(robot, ArtifactFn::Rnea, 4, QFormat::new(12, 12)).expect("engine");
        assert!(eng.run(&[vec![0.0; 28]]).is_err());
        assert!(eng.run(&[vec![0.0; 10], vec![0.0; 10], vec![0.0; 10]]).is_err());
        assert!(eng
            .rollout(&vec![0.0; n], &vec![0.0; n], &vec![0.0; n], -1.0)
            .is_err());
    }

    /// Rollouts route through the integer step path: every step matches
    /// the deferred integer FD + semi-implicit update replayed by hand,
    /// and two engines over the same request agree bitwise.
    #[test]
    fn qint_rollout_steps_through_the_integer_lane() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let fmt = QFormat::new(12, 14);
        let mut rng = Rng::new(721);
        let s0 = State::random(&robot, &mut rng);
        let q0: Vec<f32> = s0.q.iter().map(|&x| x as f32).collect();
        let qd0: Vec<f32> = s0.qd.iter().map(|&x| x as f32).collect();
        let h = 6;
        let tau: Vec<f32> =
            rng.vec_range(h * n, -2.0, 2.0).iter().map(|&x| x as f32).collect();
        let dt = 1e-3;
        let mut eng = QIntEngine::new(robot.clone(), ArtifactFn::Fd, 4, fmt).expect("engine");
        let sched = eng.schedule().clone();
        let got = eng.rollout(&q0, &qd0, &tau, dt).expect("rollout");
        assert_eq!(got.len(), 2 * h * n);
        // Replay by hand through the allocating deferred-FD wrapper.
        let mut state = State {
            q: q0.iter().map(|&x| x as f64).collect(),
            qd: qd0.iter().map(|&x| x as f64).collect(),
        };
        for t in 0..h {
            let ut: Vec<f64> = tau[t * n..(t + 1) * n].iter().map(|&x| x as f64).collect();
            let qdd = quant_fd_dd_i64(&robot, &state.q, &state.qd, &ut, &sched);
            semi_implicit_update(&mut state, &qdd, dt);
            for i in 0..n {
                assert_eq!(got[t * n + i], state.q[i] as f32, "step {t} q[{i}]");
                assert_eq!(got[(h + t) * n + i], state.qd[i] as f32, "step {t} qd[{i}]");
            }
        }
        let mut eng2 = QIntEngine::new(robot, ArtifactFn::Fd, 4, fmt).expect("engine");
        assert_eq!(eng2.rollout(&q0, &qd0, &tau, dt).expect("rollout"), got);
    }
}
