//! A compiled executable for one artifact: PJRT CPU client + loaded
//! executable + shape bookkeeping, with a batched `run` entrypoint.
//!
//! The whole PJRT path is gated behind the `pjrt` cargo feature (it needs
//! the vendored `xla` crate, unavailable offline); [`EngineError`] stays
//! unconditional because the native engine shares it. Default builds
//! serve through [`super::native::NativeEngine`] instead.

#[cfg(feature = "pjrt")]
use super::artifact::{ArtifactFn, ArtifactMeta};
use std::fmt;

/// Execution-layer failure (shape mismatch, load error, backend fault),
/// shared by the native, quantized, and PJRT engines.
#[derive(Debug)]
pub struct EngineError(
    /// Human-readable failure description.
    pub String,
);

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "engine error: {}", self.0)
    }
}

impl std::error::Error for EngineError {}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for EngineError {
    fn from(e: xla::Error) -> Self {
        EngineError(format!("{e:?}"))
    }
}

/// One compiled (robot, function, batch) executable.
#[cfg(feature = "pjrt")]
pub struct Engine {
    /// Artifact metadata (robot, function, batch, path).
    pub meta: ArtifactMeta,
    /// Joint dimension, probed from the robot description.
    pub n: usize,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Compile the artifact on a PJRT CPU client. `n` is the robot DOF
    /// (defines the operand shapes (B, N)).
    pub fn load(client: &xla::PjRtClient, meta: ArtifactMeta, n: usize) -> Result<Engine, EngineError> {
        let path = meta
            .path
            .to_str()
            .ok_or_else(|| EngineError("non-utf8 artifact path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Engine { meta, n, exe })
    }

    /// Execute one batch. `inputs` holds `arity` flat f32 arrays, each of
    /// length `batch * n` (row-major (B, N)). Returns the flat output:
    /// length `batch * n` for RNEA/FD, `batch * n * n` for Minv.
    pub fn run(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        let b = self.meta.batch;
        let n = self.n;
        if inputs.len() != self.meta.function.arity() {
            return Err(EngineError(format!(
                "expected {} operands, got {}",
                self.meta.function.arity(),
                inputs.len()
            )));
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for x in inputs {
            if x.len() != b * n {
                return Err(EngineError(format!(
                    "operand length {} != batch*n = {}",
                    x.len(),
                    b * n
                )));
            }
            let lit = xla::Literal::vec1(x).reshape(&[b as i64, n as i64])?;
            lits.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    pub fn expected_output_len(&self) -> usize {
        match self.meta.function {
            ArtifactFn::Rnea | ArtifactFn::Fd => self.meta.batch * self.n,
            ArtifactFn::Minv => self.meta.batch * self.n * self.n,
        }
    }
}

// NB: integration tests that exercise Engine against real artifacts live
// in rust/tests/integration_runtime.rs (they require `make artifacts`
// and `--features pjrt`).
