//! Native batched CPU engine: the default serving backend. Executes the
//! workspace dynamics core directly on the request path — no PJRT, no
//! artifacts, no Python — with the same flat-f32 batched interface as the
//! PJRT [`super::engine::Engine`], so the coordinator can drive either
//! interchangeably.
//!
//! One engine owns one [`DynWorkspace`]; the coordinator creates one
//! engine per worker thread, so a whole serving batch runs without a
//! single heap allocation inside the dynamics kernels.

use super::artifact::ArtifactFn;
use super::engine::EngineError;
use crate::dynamics::DynWorkspace;
use crate::model::Robot;
use crate::spatial::DMat;

/// Batched CPU executor for one (robot, function, batch) route.
pub struct NativeEngine {
    pub robot: Robot,
    pub function: ArtifactFn,
    pub batch: usize,
    n: usize,
    ws: DynWorkspace,
    // Per-task f64 staging buffers (decoded from the flat f32 operands).
    q: Vec<f64>,
    qd: Vec<f64>,
    u: Vec<f64>,
    out_vec: Vec<f64>,
    out_mat: DMat,
}

impl NativeEngine {
    pub fn new(robot: Robot, function: ArtifactFn, batch: usize) -> NativeEngine {
        let n = robot.dof();
        assert!(batch > 0, "batch must be positive");
        NativeEngine {
            ws: DynWorkspace::new(&robot),
            q: vec![0.0; n],
            qd: vec![0.0; n],
            u: vec![0.0; n],
            out_vec: vec![0.0; n],
            out_mat: DMat::zeros(n, n),
            robot,
            function,
            batch,
            n,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Flat output length for a full batch.
    pub fn expected_output_len(&self) -> usize {
        match self.function {
            ArtifactFn::Rnea | ArtifactFn::Fd => self.batch * self.n,
            ArtifactFn::Minv => self.batch * self.n * self.n,
        }
    }

    /// Execute one batch. Same layout as the PJRT engine — `inputs`
    /// holds `arity` flat f32 arrays, row-major (B, N) — but unlike a
    /// compiled fixed-shape executable the native engine accepts any
    /// B ≤ `batch`, so partial batches cost only the tasks they carry
    /// (no padding waste). Returns the flat f32 output for B rows.
    pub fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        let n = self.n;
        if inputs.len() != self.function.arity() {
            return Err(EngineError(format!(
                "expected {} operands, got {}",
                self.function.arity(),
                inputs.len()
            )));
        }
        let len0 = inputs[0].len();
        for x in inputs {
            if x.len() != len0 {
                return Err(EngineError(format!(
                    "ragged operands: {} vs {}",
                    x.len(),
                    len0
                )));
            }
        }
        if len0 == 0 || len0 % n != 0 {
            return Err(EngineError(format!("operand length {len0} not a multiple of n = {n}")));
        }
        let b = len0 / n;
        if b > self.batch {
            return Err(EngineError(format!("{b} rows exceed engine batch {}", self.batch)));
        }
        let per_task = match self.function {
            ArtifactFn::Rnea | ArtifactFn::Fd => n,
            ArtifactFn::Minv => n * n,
        };
        let mut out = vec![0.0f32; b * per_task];
        for k in 0..b {
            let span = k * n..(k + 1) * n;
            match self.function {
                ArtifactFn::Rnea => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span.clone()], &mut self.u);
                    self.ws.rnea_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        None,
                        &mut self.out_vec,
                    );
                    encode(&self.out_vec, &mut out[span]);
                }
                ArtifactFn::Fd => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span.clone()], &mut self.u);
                    self.ws.fd_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        None,
                        &mut self.out_vec,
                    );
                    encode(&self.out_vec, &mut out[span]);
                }
                ArtifactFn::Minv => {
                    decode(&inputs[0][span], &mut self.q);
                    self.ws.minv_into(&self.robot, &self.q, &mut self.out_mat);
                    encode(&self.out_mat.d, &mut out[k * n * n..(k + 1) * n * n]);
                }
            }
        }
        Ok(out)
    }
}

fn decode(src: &[f32], dst: &mut [f64]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s as f64;
    }
}

fn encode(src: &[f64], dst: &mut [f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::{fd, minv, rnea};
    use crate::model::{builtin_robot, State};
    use crate::util::rng::Rng;

    fn flat_inputs(robot: &Robot, b: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<(State, Vec<f64>)>) {
        let n = robot.dof();
        let mut rng = Rng::new(seed);
        let mut q = Vec::with_capacity(b * n);
        let mut qd = Vec::with_capacity(b * n);
        let mut u = Vec::with_capacity(b * n);
        let mut cases = Vec::with_capacity(b);
        for _ in 0..b {
            let s = State::random(robot, &mut rng);
            let uu = rng.vec_range(n, -6.0, 6.0);
            q.extend(s.q.iter().map(|&x| x as f32));
            qd.extend(s.qd.iter().map(|&x| x as f32));
            u.extend(uu.iter().map(|&x| x as f32));
            cases.push((s, uu));
        }
        (vec![q, qd, u], cases)
    }

    #[test]
    fn native_engine_matches_reference_rnea_fd() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let b = 16;
        let (inputs, cases) = flat_inputs(&robot, b, 700);
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd] {
            let mut eng = NativeEngine::new(robot.clone(), function, b);
            let out = eng.run(&inputs).expect("run");
            assert_eq!(out.len(), b * n);
            for (k, (s, u)) in cases.iter().enumerate() {
                // Reference on the f32-rounded operands the engine saw.
                let qr: Vec<f64> = s.q.iter().map(|&x| x as f32 as f64).collect();
                let qdr: Vec<f64> = s.qd.iter().map(|&x| x as f32 as f64).collect();
                let ur: Vec<f64> = u.iter().map(|&x| x as f32 as f64).collect();
                let want = match function {
                    ArtifactFn::Rnea => rnea(&robot, &qr, &qdr, &ur, None),
                    ArtifactFn::Fd => fd(&robot, &qr, &qdr, &ur, None),
                    ArtifactFn::Minv => unreachable!(),
                };
                for i in 0..n {
                    let got = out[k * n + i] as f64;
                    let scale = 1.0f64.max(want[i].abs());
                    assert!(
                        (got - want[i]).abs() / scale < 1e-5,
                        "task {k} joint {i}: {got} vs {}",
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn native_engine_matches_reference_minv() {
        let robot = builtin_robot("atlas").unwrap();
        let n = robot.dof();
        let b = 4;
        let (inputs, cases) = flat_inputs(&robot, b, 701);
        let mut eng = NativeEngine::new(robot.clone(), ArtifactFn::Minv, b);
        let out = eng.run(&inputs[..1]).expect("run");
        assert_eq!(out.len(), b * n * n);
        for (k, (s, _)) in cases.iter().enumerate() {
            let qr: Vec<f64> = s.q.iter().map(|&x| x as f32 as f64).collect();
            let want = minv(&robot, &qr);
            let scale = want.max_abs();
            for i in 0..n {
                for j in 0..n {
                    let got = out[k * n * n + i * n + j] as f64;
                    assert!(
                        (got - want[(i, j)]).abs() / scale < 1e-5,
                        "task {k} M⁻¹[{i}][{j}]: {got} vs {}",
                        want[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn native_engine_rejects_bad_shapes() {
        let robot = builtin_robot("iiwa").unwrap();
        let mut eng = NativeEngine::new(robot, ArtifactFn::Rnea, 4);
        // Wrong arity.
        assert!(eng.run(&[vec![0.0; 28]]).is_err());
        // Ragged operands.
        assert!(eng.run(&[vec![0.0; 28], vec![0.0; 28], vec![0.0; 27]]).is_err());
        // Not a multiple of n.
        assert!(eng.run(&[vec![0.0; 10], vec![0.0; 10], vec![0.0; 10]]).is_err());
        // More rows than the engine batch.
        assert!(eng.run(&[vec![0.0; 42], vec![0.0; 42], vec![0.0; 42]]).is_err());
    }

    #[test]
    fn native_engine_accepts_partial_batches() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut eng = NativeEngine::new(robot.clone(), ArtifactFn::Rnea, 16);
        // 3 rows into a batch-16 engine: output sized for 3, values match
        // the reference per row.
        let (inputs, cases) = flat_inputs(&robot, 3, 702);
        let out = eng.run(&inputs).expect("partial batch runs");
        assert_eq!(out.len(), 3 * n);
        for (k, (s, u)) in cases.iter().enumerate() {
            let qr: Vec<f64> = s.q.iter().map(|&x| x as f32 as f64).collect();
            let qdr: Vec<f64> = s.qd.iter().map(|&x| x as f32 as f64).collect();
            let ur: Vec<f64> = u.iter().map(|&x| x as f32 as f64).collect();
            let want = rnea(&robot, &qr, &qdr, &ur, None);
            for i in 0..n {
                let got = out[k * n + i] as f64;
                let scale = 1.0f64.max(want[i].abs());
                assert!((got - want[i]).abs() / scale < 1e-5, "row {k} joint {i}");
            }
        }
    }
}
