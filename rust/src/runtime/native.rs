//! Native batched CPU engine: the default serving backend. Executes the
//! workspace dynamics core directly on the request path — no PJRT, no
//! artifacts, no Python — with the same flat-f32 batched interface as the
//! PJRT [`super::engine::Engine`], so the coordinator can drive either
//! interchangeably.
//!
//! One engine owns one [`DynWorkspace`]; the coordinator creates one
//! engine per worker thread, so a whole serving batch runs without a
//! single heap allocation inside the dynamics kernels. The engine also
//! implements [`super::DynamicsEngine`], the uniform trait the batcher
//! drives for f64 and quantized execution, including trajectory
//! [`NativeEngine::rollout`] through the workspace integrator.

use super::artifact::ArtifactFn;
use super::engine::EngineError;
use super::DynamicsEngine;
use crate::dynamics::{BatchKernel, DynWorkspace, FloatMemo, WorkerPool};
use crate::model::{Robot, State};
use crate::sim::integrate::step_semi_implicit_ws;
use crate::spatial::DMat;
use std::sync::Arc;

/// Upper bound on trajectory-request horizons (steps); guards a worker
/// against a single malformed request allocating an unbounded response.
pub const MAX_HORIZON: usize = 65536;

/// Smallest batch the parallel path bothers splitting: below this the
/// channel round-trip costs more than a small-robot kernel call.
pub const PAR_MIN_ROWS: usize = 2;

/// Batched CPU executor for one (robot, function, batch) route.
pub struct NativeEngine {
    /// The robot this engine serves (shared with pool jobs, so the
    /// workers' `Arc::ptr_eq` cache fast path hits on every batch).
    pub robot: Arc<Robot>,
    /// The RBD function this route evaluates.
    pub function: ArtifactFn,
    /// Maximum tasks per executed batch.
    pub batch: usize,
    n: usize,
    /// Max chunks a batch may split into on the global worker pool
    /// (1 = serial execution on the calling thread).
    par_chunks: usize,
    ws: DynWorkspace,
    // Per-task f64 staging buffers (decoded from the flat f32 operands).
    q: Vec<f64>,
    qd: Vec<f64>,
    u: Vec<f64>,
    out_vec: Vec<f64>,
    out_mat: DMat,
    /// Fused-egress staging for `DynAll` tasks (`n² + 2n` values).
    out_all: Vec<f64>,
    /// Robot fingerprint partitioning memo entries (computed once here,
    /// matching what pool workers derive per chunk).
    robot_fp: u64,
    /// Cross-request kinematics memo for serial `DynAll` batches.
    memo: FloatMemo,
    /// Memo `(hits, misses)` accumulated from pooled `DynAll` batches
    /// (the workers' own memos; deltas returned by the pool).
    pool_hits: u64,
    pool_misses: u64,
}

impl NativeEngine {
    /// Build a serial engine (and its workspace) for one robot and
    /// function.
    pub fn new(robot: Robot, function: ArtifactFn, batch: usize) -> NativeEngine {
        NativeEngine::with_parallelism(robot, function, batch, 1)
    }

    /// As [`NativeEngine::new`], but batches of at least [`PAR_MIN_ROWS`]
    /// rows split into up to `parallel` contiguous chunks on the global
    /// [`WorkerPool`] (`0` = one chunk per pool worker, `1` = serial).
    /// Results are bitwise identical to serial execution — the pooled
    /// workers run the same decode→kernel→encode loop per task.
    pub fn with_parallelism(
        robot: Robot,
        function: ArtifactFn,
        batch: usize,
        parallel: usize,
    ) -> NativeEngine {
        let n = robot.dof();
        assert!(batch > 0, "batch must be positive");
        // Clamp to the pool size: more chunks than workers only adds
        // channel traffic, and on a 1-worker pool the serial loop below
        // beats a pool round-trip outright. `parallel == 1` never touches
        // (or spawns) the global pool.
        let par_chunks = match parallel {
            1 => 1,
            0 => WorkerPool::global().threads(),
            p => p.min(WorkerPool::global().threads()),
        };
        let robot_fp = robot.fingerprint();
        NativeEngine {
            ws: DynWorkspace::new(&robot),
            q: vec![0.0; n],
            qd: vec![0.0; n],
            u: vec![0.0; n],
            out_vec: vec![0.0; n],
            out_mat: DMat::zeros(n, n),
            out_all: vec![0.0; n * n + 2 * n],
            robot_fp,
            memo: FloatMemo::with_default_cap(),
            pool_hits: 0,
            pool_misses: 0,
            robot: Arc::new(robot),
            function,
            batch,
            n,
            par_chunks,
        }
    }

    /// Max pool chunks a batch may split into (1 = serial).
    pub fn parallelism(&self) -> usize {
        self.par_chunks
    }

    /// Robot DOF (the per-operand row length).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Flat output length for a full batch (`batch ·` the per-task size
    /// defined once by [`DynamicsEngine::out_per_task`]).
    pub fn expected_output_len(&self) -> usize {
        self.batch * DynamicsEngine::out_per_task(self)
    }

    /// Execute one batch. Same layout as the PJRT engine — `inputs`
    /// holds `arity` flat f32 arrays, row-major (B, N) — but unlike a
    /// compiled fixed-shape executable the native engine accepts any
    /// B ≤ `batch`, so partial batches cost only the tasks they carry
    /// (no padding waste). Returns the flat f32 output for B rows.
    ///
    /// When the engine was built with parallelism
    /// ([`NativeEngine::with_parallelism`]), batches of ≥
    /// [`PAR_MIN_ROWS`] rows fan out across the global [`WorkerPool`]
    /// zero-copy (the pool borrows these operand arrays in place) and the
    /// outputs are bitwise identical to the serial path below.
    pub fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        let n = self.n;
        let b = validate_batch(inputs, self.function.arity(), n, self.batch)?;
        let per_task = DynamicsEngine::out_per_task(self);
        let mut out = vec![0.0f32; b * per_task];
        if self.par_chunks > 1 && b >= PAR_MIN_ROWS {
            let kernel = match self.function {
                ArtifactFn::Rnea => BatchKernel::Rnea,
                ArtifactFn::Fd => BatchKernel::Fd,
                ArtifactFn::Minv => BatchKernel::Minv,
                ArtifactFn::DynAll => BatchKernel::DynAll,
            };
            // M⁻¹ is unary; hand the pool `q` for the unused operands.
            let (qd, u) = match self.function {
                ArtifactFn::Minv => (&inputs[0], &inputs[0]),
                _ => (&inputs[1], &inputs[2]),
            };
            let (hits, misses) = WorkerPool::global().eval_flat(
                &self.robot,
                kernel,
                &inputs[0],
                qd,
                u,
                n,
                per_task,
                &mut out,
                self.par_chunks,
            );
            self.pool_hits += hits;
            self.pool_misses += misses;
            return Ok(out);
        }
        for k in 0..b {
            let span = k * n..(k + 1) * n;
            match self.function {
                ArtifactFn::Rnea => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span.clone()], &mut self.u);
                    self.ws.rnea_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        None,
                        &mut self.out_vec,
                    );
                    encode(&self.out_vec, &mut out[span]);
                }
                ArtifactFn::Fd => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span.clone()], &mut self.u);
                    self.ws.fd_into(
                        &self.robot,
                        &self.q,
                        &self.qd,
                        &self.u,
                        None,
                        &mut self.out_vec,
                    );
                    encode(&self.out_vec, &mut out[span]);
                }
                ArtifactFn::Minv => {
                    decode(&inputs[0][span], &mut self.q);
                    self.ws.minv_into(&self.robot, &self.q, &mut self.out_mat);
                    encode(&self.out_mat.d, &mut out[k * n * n..(k + 1) * n * n]);
                }
                ArtifactFn::DynAll => {
                    decode(&inputs[0][span.clone()], &mut self.q);
                    decode(&inputs[1][span.clone()], &mut self.qd);
                    decode(&inputs[2][span], &mut self.u);
                    self.ws.dyn_all_memo_into(
                        &self.robot,
                        self.robot_fp,
                        &self.q,
                        &self.qd,
                        &self.u,
                        &mut self.memo,
                        &mut self.out_all,
                    );
                    encode(&self.out_all, &mut out[k * per_task..(k + 1) * per_task]);
                }
            }
        }
        Ok(out)
    }

    /// Unroll one trajectory request through the workspace integrator
    /// ([`step_semi_implicit_ws`], i.e. O(N) ABA + semi-implicit Euler).
    /// `tau` holds H torque rows of length N (row-major); the response is
    /// flat f32 of length `2·H·N`: all H q-rows, then all H q̇-rows.
    /// Built on [`NativeEngine::rollout_stream`], so the buffered and
    /// streamed egress are bitwise identical by construction.
    pub fn rollout(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
    ) -> Result<Vec<f32>, EngineError> {
        let n = self.n;
        let h = validate_rollout(q0, qd0, tau, dt, n)?;
        let mut out = vec![0.0f32; 2 * h * n];
        let mut t = 0usize;
        self.rollout_stream(q0, qd0, tau, dt, &mut |row| {
            out[t * n..(t + 1) * n].copy_from_slice(&row[..n]);
            out[(h + t) * n..(h + t + 1) * n].copy_from_slice(&row[n..]);
            t += 1;
            true
        })?;
        Ok(out)
    }

    /// Streaming rollout: `emit` receives the encoded row `q_t ‖ q̇_t`
    /// (length `2·N`) after **each** integration step — the row leaves
    /// the engine before step `t+1` runs, which is what lets the network
    /// layer flush trajectory chunks mid-horizon. `emit` returning
    /// `false` cancels the remaining steps. Returns the emitted count.
    pub fn rollout_stream(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
        emit: &mut dyn FnMut(&[f32]) -> bool,
    ) -> Result<usize, EngineError> {
        let n = self.n;
        let h = validate_rollout(q0, qd0, tau, dt, n)?;
        decode(q0, &mut self.q);
        decode(qd0, &mut self.qd);
        let mut state =
            State { q: std::mem::take(&mut self.q), qd: std::mem::take(&mut self.qd) };
        let mut row = vec![0.0f32; 2 * n];
        let mut emitted = h;
        for t in 0..h {
            decode(&tau[t * n..(t + 1) * n], &mut self.u);
            step_semi_implicit_ws(
                &self.robot,
                &mut self.ws,
                &mut self.out_vec,
                &mut state,
                &self.u,
                None,
                dt,
            );
            encode(&state.q, &mut row[..n]);
            encode(&state.qd, &mut row[n..]);
            if !emit(&row) {
                emitted = t + 1;
                break;
            }
        }
        self.q = state.q;
        self.qd = state.qd;
        Ok(emitted)
    }
}

impl DynamicsEngine for NativeEngine {
    fn robot(&self) -> &Robot {
        &self.robot
    }
    fn function(&self) -> ArtifactFn {
        self.function
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn n(&self) -> usize {
        self.n
    }
    fn memo_counters(&self) -> (u64, u64) {
        let (h, m) = self.memo.counters();
        (h + self.pool_hits, m + self.pool_misses)
    }
    fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        NativeEngine::run(self, inputs)
    }
    fn rollout(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
    ) -> Result<Vec<f32>, EngineError> {
        NativeEngine::rollout(self, q0, qd0, tau, dt)
    }
    fn rollout_stream(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
        emit: &mut dyn FnMut(&[f32]) -> bool,
    ) -> Result<usize, EngineError> {
        NativeEngine::rollout_stream(self, q0, qd0, tau, dt, emit)
    }
}

/// Shared operand validation for the flat batched interface: checks
/// arity, raggedness, row alignment, and the engine batch bound. Returns
/// the row count B.
pub(crate) fn validate_batch(
    inputs: &[Vec<f32>],
    arity: usize,
    n: usize,
    batch: usize,
) -> Result<usize, EngineError> {
    if inputs.len() != arity {
        return Err(EngineError(format!("expected {arity} operands, got {}", inputs.len())));
    }
    let len0 = inputs[0].len();
    for x in inputs {
        if x.len() != len0 {
            return Err(EngineError(format!("ragged operands: {} vs {}", x.len(), len0)));
        }
    }
    if len0 == 0 || len0 % n != 0 {
        return Err(EngineError(format!("operand length {len0} not a multiple of n = {n}")));
    }
    let b = len0 / n;
    if b > batch {
        return Err(EngineError(format!("{b} rows exceed engine batch {batch}")));
    }
    Ok(b)
}

/// Shared trajectory-request validation. Returns the horizon H.
pub(crate) fn validate_rollout(
    q0: &[f32],
    qd0: &[f32],
    tau: &[f32],
    dt: f64,
    n: usize,
) -> Result<usize, EngineError> {
    if q0.len() != n || qd0.len() != n {
        return Err(EngineError(format!(
            "initial state length {}/{} != n = {n}",
            q0.len(),
            qd0.len()
        )));
    }
    if tau.is_empty() || tau.len() % n != 0 {
        return Err(EngineError(format!(
            "torque sequence length {} not a positive multiple of n = {n}",
            tau.len()
        )));
    }
    let h = tau.len() / n;
    if h > MAX_HORIZON {
        return Err(EngineError(format!("horizon {h} exceeds maximum {MAX_HORIZON}")));
    }
    if !(dt.is_finite() && dt > 0.0) {
        return Err(EngineError(format!("bad dt {dt}")));
    }
    Ok(h)
}

pub(crate) fn decode(src: &[f32], dst: &mut [f64]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s as f64;
    }
}

pub(crate) fn encode(src: &[f64], dst: &mut [f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::{fd, minv, rnea};
    use crate::model::{builtin_robot, State};
    use crate::sim::integrate::step_semi_implicit;
    use crate::util::rng::Rng;

    fn flat_inputs(robot: &Robot, b: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<(State, Vec<f64>)>) {
        let n = robot.dof();
        let mut rng = Rng::new(seed);
        let mut q = Vec::with_capacity(b * n);
        let mut qd = Vec::with_capacity(b * n);
        let mut u = Vec::with_capacity(b * n);
        let mut cases = Vec::with_capacity(b);
        for _ in 0..b {
            let s = State::random(robot, &mut rng);
            let uu = rng.vec_range(n, -6.0, 6.0);
            q.extend(s.q.iter().map(|&x| x as f32));
            qd.extend(s.qd.iter().map(|&x| x as f32));
            u.extend(uu.iter().map(|&x| x as f32));
            cases.push((s, uu));
        }
        (vec![q, qd, u], cases)
    }

    /// Streamed rows are bitwise identical to the buffered rollout, and
    /// an emit callback returning `false` cancels the remaining horizon
    /// mid-flight — the control actually returns before step H runs,
    /// which is the property the chunked network egress relies on.
    #[test]
    fn rollout_stream_matches_buffered_and_cancels_mid_horizon() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let h = 12;
        let mut rng = Rng::new(730);
        let s0 = State::random(&robot, &mut rng);
        let q0: Vec<f32> = s0.q.iter().map(|&x| x as f32).collect();
        let qd0: Vec<f32> = s0.qd.iter().map(|&x| x as f32).collect();
        let tau: Vec<f32> = rng.vec_range(h * n, -2.0, 2.0).iter().map(|&x| x as f32).collect();
        let dt = 1e-3;
        let mut eng = NativeEngine::new(robot.clone(), ArtifactFn::Fd, 4);
        let flat = eng.rollout(&q0, &qd0, &tau, dt).expect("buffered rollout");
        let mut eng2 = NativeEngine::new(robot.clone(), ArtifactFn::Fd, 4);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        let emitted = eng2
            .rollout_stream(&q0, &qd0, &tau, dt, &mut |row| {
                rows.push(row.to_vec());
                true
            })
            .expect("streamed rollout");
        assert_eq!(emitted, h);
        assert_eq!(rows.len(), h);
        for (t, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), 2 * n);
            assert_eq!(&row[..n], &flat[t * n..(t + 1) * n], "q row {t}");
            assert_eq!(&row[n..], &flat[(h + t) * n..(h + t + 1) * n], "qd row {t}");
        }
        // Cancellation: stop after 3 rows; exactly 3 must have been
        // emitted (no step 4 ran before control returned).
        let mut eng3 = NativeEngine::new(robot, ArtifactFn::Fd, 4);
        let mut seen = 0usize;
        let emitted = eng3
            .rollout_stream(&q0, &qd0, &tau, dt, &mut |_| {
                seen += 1;
                seen < 3
            })
            .expect("cancelled rollout");
        assert_eq!(emitted, 3);
        assert_eq!(seen, 3);
    }

    #[test]
    fn native_engine_matches_reference_rnea_fd() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let b = 16;
        let (inputs, cases) = flat_inputs(&robot, b, 700);
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd] {
            let mut eng = NativeEngine::new(robot.clone(), function, b);
            let out = eng.run(&inputs).expect("run");
            assert_eq!(out.len(), b * n);
            for (k, (s, u)) in cases.iter().enumerate() {
                // Reference on the f32-rounded operands the engine saw.
                let qr: Vec<f64> = s.q.iter().map(|&x| x as f32 as f64).collect();
                let qdr: Vec<f64> = s.qd.iter().map(|&x| x as f32 as f64).collect();
                let ur: Vec<f64> = u.iter().map(|&x| x as f32 as f64).collect();
                let want = match function {
                    ArtifactFn::Rnea => rnea(&robot, &qr, &qdr, &ur, None),
                    ArtifactFn::Fd => fd(&robot, &qr, &qdr, &ur, None),
                    ArtifactFn::Minv => unreachable!(),
                };
                for i in 0..n {
                    let got = out[k * n + i] as f64;
                    let scale = 1.0f64.max(want[i].abs());
                    assert!(
                        (got - want[i]).abs() / scale < 1e-5,
                        "task {k} joint {i}: {got} vs {}",
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn native_engine_matches_reference_minv() {
        let robot = builtin_robot("atlas").unwrap();
        let n = robot.dof();
        let b = 4;
        let (inputs, cases) = flat_inputs(&robot, b, 701);
        let mut eng = NativeEngine::new(robot.clone(), ArtifactFn::Minv, b);
        let out = eng.run(&inputs[..1]).expect("run");
        assert_eq!(out.len(), b * n * n);
        for (k, (s, _)) in cases.iter().enumerate() {
            let qr: Vec<f64> = s.q.iter().map(|&x| x as f32 as f64).collect();
            let want = minv(&robot, &qr);
            let scale = want.max_abs();
            for i in 0..n {
                for j in 0..n {
                    let got = out[k * n * n + i * n + j] as f64;
                    assert!(
                        (got - want[(i, j)]).abs() / scale < 1e-5,
                        "task {k} M⁻¹[{i}][{j}]: {got} vs {}",
                        want[(i, j)]
                    );
                }
            }
        }
    }

    /// The fused DynAll route: serial output matches the memo-less fused
    /// kernel per row bitwise, a repeated batch is answered from the
    /// memo (counters advance, output identical), and a pooled engine
    /// reproduces the serial rows bitwise while surfacing the workers'
    /// memo deltas.
    #[test]
    fn native_engine_serves_dyn_all_with_memo() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let b = 6;
        let per = n * n + 2 * n;
        let (inputs, _) = flat_inputs(&robot, b, 704);
        let mut eng = NativeEngine::new(robot.clone(), ArtifactFn::DynAll, b);
        assert_eq!(DynamicsEngine::out_per_task(&eng), per);
        let out = eng.run(&inputs).expect("run");
        assert_eq!(out.len(), b * per);
        // Reference: the memo-less fused kernel on the decoded rows.
        let mut ws = crate::dynamics::DynWorkspace::new(&robot);
        let (mut q, mut qd, mut u) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let mut want = vec![0.0f64; per];
        for k in 0..b {
            decode(&inputs[0][k * n..(k + 1) * n], &mut q);
            decode(&inputs[1][k * n..(k + 1) * n], &mut qd);
            decode(&inputs[2][k * n..(k + 1) * n], &mut u);
            ws.dyn_all_into(&robot, &q, &qd, &u, None, &mut want);
            for (i, w) in want.iter().enumerate() {
                assert_eq!(out[k * per + i], *w as f32, "row {k} value {i}");
            }
        }
        assert_eq!(eng.memo_counters(), (0, b as u64), "cold batch: all misses");
        // The identical batch again: all memo hits, identical output.
        let again = eng.run(&inputs).expect("warm run");
        assert_eq!(again, out, "memo hits must replay the sweep bitwise");
        assert_eq!(eng.memo_counters(), (b as u64, b as u64));
        // Pooled engine: bitwise identical rows, worker memo deltas
        // surface through the engine counters.
        let mut par = NativeEngine::with_parallelism(robot, ArtifactFn::DynAll, b, 0);
        let pout = par.run(&inputs).expect("pooled run");
        assert_eq!(pout, out, "pooled dyn_all diverged from serial");
        let (h, m) = par.memo_counters();
        assert_eq!(h + m, b as u64, "every pooled row hit or missed exactly once");
    }

    #[test]
    fn native_engine_rejects_bad_shapes() {
        let robot = builtin_robot("iiwa").unwrap();
        let mut eng = NativeEngine::new(robot, ArtifactFn::Rnea, 4);
        // Wrong arity.
        assert!(eng.run(&[vec![0.0; 28]]).is_err());
        // Ragged operands.
        assert!(eng.run(&[vec![0.0; 28], vec![0.0; 28], vec![0.0; 27]]).is_err());
        // Not a multiple of n.
        assert!(eng.run(&[vec![0.0; 10], vec![0.0; 10], vec![0.0; 10]]).is_err());
        // More rows than the engine batch.
        assert!(eng.run(&[vec![0.0; 42], vec![0.0; 42], vec![0.0; 42]]).is_err());
    }

    #[test]
    fn native_engine_accepts_partial_batches() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut eng = NativeEngine::new(robot.clone(), ArtifactFn::Rnea, 16);
        // 3 rows into a batch-16 engine: output sized for 3, values match
        // the reference per row.
        let (inputs, cases) = flat_inputs(&robot, 3, 702);
        let out = eng.run(&inputs).expect("partial batch runs");
        assert_eq!(out.len(), 3 * n);
        for (k, (s, u)) in cases.iter().enumerate() {
            let qr: Vec<f64> = s.q.iter().map(|&x| x as f32 as f64).collect();
            let qdr: Vec<f64> = s.qd.iter().map(|&x| x as f32 as f64).collect();
            let ur: Vec<f64> = u.iter().map(|&x| x as f32 as f64).collect();
            let want = rnea(&robot, &qr, &qdr, &ur, None);
            for i in 0..n {
                let got = out[k * n + i] as f64;
                let scale = 1.0f64.max(want[i].abs());
                assert!((got - want[i]).abs() / scale < 1e-5, "row {k} joint {i}");
            }
        }
    }

    #[test]
    fn rollout_matches_per_step_integration() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut rng = Rng::new(703);
        let s0 = State::random(&robot, &mut rng);
        let h = 12;
        let dt = 1e-3;
        let tau_f64 = rng.vec_range(h * n, -4.0, 4.0);
        let q0: Vec<f32> = s0.q.iter().map(|&x| x as f32).collect();
        let qd0: Vec<f32> = s0.qd.iter().map(|&x| x as f32).collect();
        let tau: Vec<f32> = tau_f64.iter().map(|&x| x as f32).collect();

        let mut eng = NativeEngine::new(robot.clone(), ArtifactFn::Fd, 8);
        let out = eng.rollout(&q0, &qd0, &tau, dt).expect("rollout");
        assert_eq!(out.len(), 2 * h * n);

        // Reference: per-step allocating integrator from the f32-rounded
        // initial state and torques (exactly what the engine decoded).
        let mut state = State {
            q: q0.iter().map(|&x| x as f64).collect(),
            qd: qd0.iter().map(|&x| x as f64).collect(),
        };
        for t in 0..h {
            let tt: Vec<f64> = tau[t * n..(t + 1) * n].iter().map(|&x| x as f64).collect();
            step_semi_implicit(&robot, &mut state, &tt, None, dt);
            for i in 0..n {
                let got_q = out[t * n + i] as f64;
                let got_qd = out[(h + t) * n + i] as f64;
                let sq = 1.0f64.max(state.q[i].abs());
                let sqd = 1.0f64.max(state.qd[i].abs());
                assert!(
                    (got_q - state.q[i]).abs() / sq < 1e-5,
                    "step {t} q[{i}]: {got_q} vs {}",
                    state.q[i]
                );
                assert!(
                    (got_qd - state.qd[i]).abs() / sqd < 1e-5,
                    "step {t} qd[{i}]: {got_qd} vs {}",
                    state.qd[i]
                );
            }
        }
    }

    #[test]
    fn rollout_rejects_bad_requests() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut eng = NativeEngine::new(robot, ArtifactFn::Fd, 8);
        // Wrong initial-state length.
        assert!(eng.rollout(&vec![0.0; n - 1], &vec![0.0; n], &vec![0.0; n], 1e-3).is_err());
        // Empty torque sequence.
        assert!(eng.rollout(&vec![0.0; n], &vec![0.0; n], &[], 1e-3).is_err());
        // Misaligned torque sequence.
        assert!(eng.rollout(&vec![0.0; n], &vec![0.0; n], &vec![0.0; n + 1], 1e-3).is_err());
        // Bad dt.
        assert!(eng.rollout(&vec![0.0; n], &vec![0.0; n], &vec![0.0; n], 0.0).is_err());
        assert!(eng.rollout(&vec![0.0; n], &vec![0.0; n], &vec![0.0; n], f64::NAN).is_err());
    }
}
