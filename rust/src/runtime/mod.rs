//! Execution runtimes behind the serving coordinator.
//!
//! * [`native`] — the default: a batched CPU engine running the
//!   allocation-free workspace dynamics core directly. No external
//!   toolchain, no artifacts; this is the path `draco serve` uses out of
//!   the box.
//! * [`engine`] (feature `pjrt`) — load AOT-compiled HLO-text artifacts
//!   (produced once by `python/compile/aot.py`) and execute them through
//!   PJRT. Python is never on this path — the artifacts are
//!   self-contained. Interchange is HLO *text* (not serialized protos):
//!   jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md).

pub mod artifact;
pub mod engine;
pub mod native;

pub use artifact::{scan_artifacts, ArtifactMeta};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use engine::EngineError;
pub use native::NativeEngine;
