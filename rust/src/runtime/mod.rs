//! Execution runtimes behind the serving coordinator.
//!
//! * [`native`] — the default: a batched CPU engine running the
//!   allocation-free workspace dynamics core directly. No external
//!   toolchain, no artifacts; this is the path `draco serve` uses out of
//!   the box.
//! * [`quantized`] — the fixed-point twin of the native engine: the same
//!   batched interface evaluated through `quant::qrbd` at a per-robot
//!   `QFormat`, so precision (and, on the accelerator, DSP cost) is a
//!   per-robot serving knob.
//! * [`qint`] — the true-integer `i64` lane as a serving backend: the
//!   same interface through `quant::qint`'s scaled-once constants and
//!   integer inner loops, with FD/M⁻¹ on the division-deferring sweeps
//!   under a shift schedule proved at construction by the fixed-point
//!   scaling analysis (`quant::scaling`). Construction fails with the
//!   overflow witness instead of degrading to the rounded lane.
//! * [`chaos`] — fault-injection wrapper over the native engine
//!   (injectable panics + a capacity throttle) used by the robustness
//!   tests and the `draco loadgen` overload harness.
//! * [`engine`] (feature `pjrt`) — load AOT-compiled HLO-text artifacts
//!   (produced once by `python/compile/aot.py`) and execute them through
//!   PJRT. Python is never on this path — the artifacts are
//!   self-contained. Interchange is HLO *text* (not serialized protos):
//!   jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md).
//!
//! The CPU engines implement [`DynamicsEngine`], the uniform trait the
//! coordinator's batching loop drives, so a route's precision is chosen
//! at registration time and invisible to the batcher.

#![warn(missing_docs)]

pub mod artifact;
pub mod chaos;
pub mod engine;
pub mod native;
pub mod qint;
pub mod quantized;

use crate::model::Robot;

pub use artifact::{scan_artifacts, ArtifactFn, ArtifactMeta};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use chaos::ChaosEngine;
pub use engine::EngineError;
pub use native::NativeEngine;
pub use qint::QIntEngine;
pub use quantized::QuantEngine;

/// Uniform interface over the batched CPU execution backends (f64
/// [`NativeEngine`], rounded fixed-point [`QuantEngine`], and the
/// true-integer [`QIntEngine`]). The coordinator
/// drives one boxed engine per worker thread; both entry points use the
/// flat-f32 wire layout so backends are interchangeable per route.
pub trait DynamicsEngine: Send {
    /// The robot this engine serves.
    fn robot(&self) -> &Robot;
    /// The RBD function step batches evaluate.
    fn function(&self) -> ArtifactFn;
    /// Maximum tasks per executed batch.
    fn batch(&self) -> usize;
    /// Robot DOF (the per-operand row length).
    fn n(&self) -> usize;
    /// Flat f32 output length per task (N for RNEA/FD, N² for M⁻¹,
    /// N²+2N for the fused DynAll egress `[q̈ | M⁻¹ | C]`).
    fn out_per_task(&self) -> usize {
        match self.function() {
            ArtifactFn::Minv => self.n() * self.n(),
            ArtifactFn::DynAll => self.n() * self.n() + 2 * self.n(),
            _ => self.n(),
        }
    }
    /// Cumulative `(hits, misses)` of the engine's cross-request
    /// kinematics memo (serial memo plus any pooled per-worker memo
    /// deltas). `(0, 0)` for engines/functions without a memo — only
    /// the `DynAll` route consults one.
    fn memo_counters(&self) -> (u64, u64) {
        (0, 0)
    }
    /// Execute one step batch: `arity` flat f32 operands, row-major
    /// (B, N), any B ≤ [`DynamicsEngine::batch`]; returns B output rows.
    fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError>;
    /// Unroll one trajectory request (H torque rows from an initial
    /// state) through the engine's integrator; returns `2·H·N` f32 —
    /// H q-rows then H q̇-rows.
    fn rollout(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
    ) -> Result<Vec<f32>, EngineError>;
    /// Streaming twin of [`DynamicsEngine::rollout`]: `emit` is called
    /// once per integration step with the encoded row `q_t ‖ q̇_t`
    /// (length `2·N`) **as the integrator produces it** — the egress
    /// path the network layer chunks on. Returning `false` from `emit`
    /// cancels the remaining horizon (the engine state still advances
    /// only through the emitted steps). Returns the number of steps
    /// emitted.
    ///
    /// The default implementation runs the full [`DynamicsEngine::rollout`]
    /// and replays its rows (correct but unstreamed); the CPU engines
    /// override it with true per-step emission, and reimplement
    /// `rollout` on top of it so both entry points are bitwise
    /// identical by construction.
    fn rollout_stream(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
        emit: &mut dyn FnMut(&[f32]) -> bool,
    ) -> Result<usize, EngineError> {
        let n = self.n();
        let flat = self.rollout(q0, qd0, tau, dt)?;
        let h = flat.len() / (2 * n);
        let mut row = vec![0.0f32; 2 * n];
        for t in 0..h {
            row[..n].copy_from_slice(&flat[t * n..(t + 1) * n]);
            row[n..].copy_from_slice(&flat[(h + t) * n..(h + t + 1) * n]);
            if !emit(&row) {
                return Ok(t + 1);
            }
        }
        Ok(h)
    }
}
