//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py`) and execute them from the request path.
//! Python is never on this path — the artifacts are self-contained.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod engine;

pub use artifact::{scan_artifacts, ArtifactMeta};
pub use engine::{Engine, EngineError};
