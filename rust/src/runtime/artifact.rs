//! Artifact discovery and metadata.
//!
//! Artifacts follow the naming convention emitted by `aot.py`:
//! `<robot>_<function>_b<batch>.hlo.txt`, e.g. `iiwa_rnea_b64.hlo.txt`,
//! accompanied by a manifest entry describing shapes.

use std::path::{Path, PathBuf};

/// Functions servable from artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArtifactFn {
    /// τ = RNEA(q, q̇, q̈): 3 inputs (B,N) → 1 output (B,N).
    Rnea,
    /// q̈ = FD(q, q̇, τ): 3 inputs (B,N) → 1 output (B,N).
    Fd,
    /// M⁻¹(q): 1 input (B,N) → 1 output (B,N,N).
    Minv,
    /// Fused multi-output dynamics at one (q, q̇): 3 inputs (B,N) →
    /// one flat (B, N²+2N) output laid out `[q̈ (N) | M⁻¹ (N·N) | C (N)]`
    /// per task — FD, M⁻¹, and the bias torques from a single kinematic
    /// sweep (the inter-module-reuse route).
    DynAll,
}

impl ArtifactFn {
    /// Parse a function tag as it appears in artifact filenames.
    pub fn parse(s: &str) -> Option<ArtifactFn> {
        match s {
            "rnea" | "id" => Some(ArtifactFn::Rnea),
            "fd" => Some(ArtifactFn::Fd),
            "minv" => Some(ArtifactFn::Minv),
            // Underscore-free canonical tag (artifact stems split on '_'),
            // with the snake-case alias accepted for CLI ergonomics.
            "dynall" | "dyn_all" => Some(ArtifactFn::DynAll),
            _ => None,
        }
    }

    /// Canonical short name (matches the artifact filename tag).
    pub fn name(&self) -> &'static str {
        match self {
            ArtifactFn::Rnea => "rnea",
            ArtifactFn::Fd => "fd",
            ArtifactFn::Minv => "minv",
            ArtifactFn::DynAll => "dynall",
        }
    }

    /// Number of (B,N) input operands.
    pub fn arity(&self) -> usize {
        match self {
            ArtifactFn::Rnea | ArtifactFn::Fd | ArtifactFn::DynAll => 3,
            ArtifactFn::Minv => 1,
        }
    }
}

/// Metadata parsed from an artifact filename.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Robot name the artifact was compiled for.
    pub robot: String,
    /// RBD function the artifact evaluates.
    pub function: ArtifactFn,
    /// Compiled-in batch size (PJRT shapes are fixed).
    pub batch: usize,
    /// Path of the HLO-text file.
    pub path: PathBuf,
}

impl ArtifactMeta {
    /// Parse `<robot>_<fn>_b<batch>.hlo.txt`.
    pub fn from_path(path: &Path) -> Option<ArtifactMeta> {
        let stem = path.file_name()?.to_str()?.strip_suffix(".hlo.txt")?;
        let mut parts = stem.rsplitn(3, '_');
        let batch_part = parts.next()?;
        let fn_part = parts.next()?;
        let robot = parts.next()?.to_string();
        let batch: usize = batch_part.strip_prefix('b')?.parse().ok()?;
        let function = ArtifactFn::parse(fn_part)?;
        Some(ArtifactMeta { robot, function, batch, path: path.to_path_buf() })
    }
}

/// Scan a directory for artifacts.
pub fn scan_artifacts(dir: &Path) -> Vec<ArtifactMeta> {
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            if let Some(meta) = ArtifactMeta::from_path(&e.path()) {
                out.push(meta);
            }
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_valid_names() {
        let m = ArtifactMeta::from_path(Path::new("artifacts/iiwa_rnea_b64.hlo.txt")).unwrap();
        assert_eq!(m.robot, "iiwa");
        assert_eq!(m.function, ArtifactFn::Rnea);
        assert_eq!(m.batch, 64);
        let m = ArtifactMeta::from_path(Path::new("atlas_minv_b1.hlo.txt")).unwrap();
        assert_eq!(m.function, ArtifactFn::Minv);
        assert_eq!(m.batch, 1);
    }

    #[test]
    fn parses_dyn_all_tags() {
        let m = ArtifactMeta::from_path(Path::new("iiwa_dynall_b8.hlo.txt")).unwrap();
        assert_eq!(m.function, ArtifactFn::DynAll);
        assert_eq!(m.robot, "iiwa");
        assert_eq!(ArtifactFn::parse("dyn_all"), Some(ArtifactFn::DynAll));
        assert_eq!(ArtifactFn::DynAll.name(), "dynall");
        assert_eq!(ArtifactFn::DynAll.arity(), 3);
    }

    #[test]
    fn rejects_other_files() {
        assert!(ArtifactMeta::from_path(Path::new("README.md")).is_none());
        assert!(ArtifactMeta::from_path(Path::new("iiwa_rnea.hlo.txt")).is_none());
        assert!(ArtifactMeta::from_path(Path::new("iiwa_frobnicate_b8.hlo.txt")).is_none());
        assert!(ArtifactMeta::from_path(Path::new("iiwa_rnea_bx.hlo.txt")).is_none());
    }

    #[test]
    fn robot_names_with_underscores() {
        let m =
            ArtifactMeta::from_path(Path::new("my_bot_fd_b8.hlo.txt")).expect("parse");
        assert_eq!(m.robot, "my_bot");
        assert_eq!(m.function, ArtifactFn::Fd);
    }

    #[test]
    fn scan_empty_dir_ok() {
        let out = scan_artifacts(Path::new("/nonexistent-dir-xyz"));
        assert!(out.is_empty());
    }
}
