//! Fault-injection engine for robustness testing and the load-generator
//! harness.
//!
//! [`ChaosEngine`] wraps the native f64 engine with two controlled
//! faults:
//!
//! * **Injected panics** — an *infinite* value anywhere in the first
//!   operand makes the evaluation panic. Infinity is a safe sentinel:
//!   normal traffic (random states, ramps, steps) never produces an
//!   infinite joint coordinate, so only deliberately poisoned requests
//!   trip it. The coordinator's batch-boundary `catch_unwind` and
//!   circuit breaker are exercised end-to-end this way.
//! * **Throttling** — a fixed per-execution sleep (`delay_us`) pins the
//!   route's capacity at ~`batch / delay_us` tasks per µs, so overload
//!   scenarios ("offer 2× capacity") are deterministic instead of
//!   depending on how fast the host evaluates dynamics.

use super::native::NativeEngine;
use super::{ArtifactFn, DynamicsEngine, EngineError};
use crate::model::Robot;
use std::time::Duration;

/// Native f64 engine wrapped with injectable panics and a capacity
/// throttle. See the module docs for the fault model.
pub struct ChaosEngine {
    inner: NativeEngine,
    delay_us: u64,
}

impl ChaosEngine {
    /// Wrap the native engine for `robot`/`function` at `batch`, adding
    /// a `delay_us` sleep to every execution (`0` = no throttle).
    pub fn new(robot: Robot, function: ArtifactFn, batch: usize, delay_us: u64) -> ChaosEngine {
        ChaosEngine { inner: NativeEngine::new(robot, function, batch), delay_us }
    }

    /// Panic when the poisoned-request sentinel (an infinite value) is
    /// present.
    fn trip_on_sentinel(values: &[f32]) {
        if values.iter().any(|x| x.is_infinite()) {
            panic!("chaos: injected engine panic (infinite operand sentinel)");
        }
    }

    fn throttle(&self) {
        if self.delay_us > 0 {
            std::thread::sleep(Duration::from_micros(self.delay_us));
        }
    }
}

impl DynamicsEngine for ChaosEngine {
    fn robot(&self) -> &Robot {
        self.inner.robot()
    }
    fn function(&self) -> ArtifactFn {
        self.inner.function()
    }
    fn batch(&self) -> usize {
        self.inner.batch()
    }
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn run(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, EngineError> {
        if let Some(first) = inputs.first() {
            ChaosEngine::trip_on_sentinel(first);
        }
        self.throttle();
        self.inner.run(inputs)
    }
    fn rollout(
        &mut self,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
        dt: f64,
    ) -> Result<Vec<f32>, EngineError> {
        ChaosEngine::trip_on_sentinel(q0);
        self.throttle();
        self.inner.rollout(q0, qd0, tau, dt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin_robot;

    #[test]
    fn clean_inputs_pass_through_to_the_native_engine() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut chaos = ChaosEngine::new(robot, ArtifactFn::Fd, 2, 0);
        let inputs = vec![vec![0.1; 2 * n], vec![0.0; 2 * n], vec![0.0; 2 * n]];
        let out = chaos.run(&inputs).expect("clean batch evaluates");
        assert_eq!(out.len(), 2 * n);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn infinite_sentinel_panics() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut chaos = ChaosEngine::new(robot, ArtifactFn::Fd, 2, 0);
        let mut q = vec![0.1; 2 * n];
        q[3] = f32::INFINITY;
        let inputs = vec![q, vec![0.0; 2 * n], vec![0.0; 2 * n]];
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| chaos.run(&inputs)));
        assert!(hit.is_err(), "the sentinel must panic, not evaluate");
    }

    #[test]
    fn throttle_delays_execution() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let mut chaos = ChaosEngine::new(robot, ArtifactFn::Fd, 1, 5_000);
        let inputs = vec![vec![0.1; n], vec![0.0; n], vec![0.0; n]];
        let t0 = std::time::Instant::now();
        chaos.run(&inputs).expect("throttled batch still evaluates");
        assert!(t0.elapsed() >= Duration::from_micros(5_000), "delay must apply");
    }
}
