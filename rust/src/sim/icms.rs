//! ICMS — Iterative Control and Motion Simulator (Fig. 4): closed-loop
//! simulation where the controller evaluates its RBD terms through a
//! selectable backend (float or quantized) while the *physics* always
//! integrates exact f64 dynamics. Paired runs (float-controlled vs
//! quantized-controlled) expose quantization effects at the three stages
//! the paper measures: RBD output, control torque, and final motion.

use crate::control::backend::{Controller, RbdBackend};
use crate::control::lqr::LqrController;
use crate::control::mpc::MpcController;
use crate::control::pid::PidController;
use crate::dynamics::DynWorkspace;
use crate::model::{Robot, State};
use crate::quant::qformat::QFormat;
use crate::sim::fk::ee_position;
use crate::sim::integrate::step_semi_implicit_ws;
use crate::sim::traj::Trajectory;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControllerKind {
    Pid,
    Lqr,
    Mpc,
}

impl ControllerKind {
    pub fn name(&self) -> &'static str {
        match self {
            ControllerKind::Pid => "pid",
            ControllerKind::Lqr => "lqr",
            ControllerKind::Mpc => "mpc",
        }
    }
}

#[derive(Debug, Clone)]
pub struct IcmsConfig {
    pub controller: ControllerKind,
    pub dt: f64,
    pub steps: usize,
    /// Control decimation: controller runs every `ctl_every` physics steps.
    pub ctl_every: usize,
    pub traj: Trajectory,
}

impl IcmsConfig {
    pub fn default_for(robot: &Robot, controller: ControllerKind) -> IcmsConfig {
        IcmsConfig {
            controller,
            dt: 1e-3,
            steps: 1500,
            ctl_every: if controller == ControllerKind::Mpc { 5 } else { 1 },
            traj: Trajectory::gentle_sinusoid(robot, 0.2, 1.2),
        }
    }
}

/// Time series from one closed-loop run.
#[derive(Debug, Clone)]
pub struct RunLog {
    pub t: Vec<f64>,
    pub q: Vec<Vec<f64>>,
    pub tau: Vec<Vec<f64>>,
    pub ee: Vec<[f64; 3]>,
    pub mpc_cost: Vec<f64>,
}

fn make_controller(
    robot: &Robot,
    cfg: &IcmsConfig,
    backend: RbdBackend,
) -> Box<dyn Controller> {
    match cfg.controller {
        ControllerKind::Pid => {
            Box::new(PidController::new(robot.clone(), backend, cfg.traj.clone()))
        }
        ControllerKind::Lqr => Box::new(LqrController::new(
            robot.clone(),
            backend,
            cfg.traj.clone(),
            cfg.dt * cfg.ctl_every as f64,
        )),
        ControllerKind::Mpc => Box::new(MpcController::new(
            robot.clone(),
            backend,
            cfg.traj.clone(),
            cfg.dt * cfg.ctl_every as f64,
        )),
    }
}

/// Run one closed loop with the given backend.
pub fn run_closed_loop(robot: &Robot, cfg: &IcmsConfig, backend: RbdBackend) -> RunLog {
    let n = robot.dof();
    let mut ctl = make_controller(robot, cfg, backend);
    let (q0, qd0, _) = cfg.traj.sample(0.0);
    let mut s = State { q: q0, qd: qd0 };
    let mut log = RunLog {
        t: Vec::new(),
        q: Vec::new(),
        tau: Vec::new(),
        ee: Vec::new(),
        mpc_cost: Vec::new(),
    };
    // Physics fast path: one workspace reused across every step, so the
    // exact-dynamics integrator allocates nothing per step.
    let mut ws = DynWorkspace::new(robot);
    let mut qdd = vec![0.0; n];
    let mut tau = vec![0.0; n];
    for k in 0..cfg.steps {
        let t = k as f64 * cfg.dt;
        if k % cfg.ctl_every == 0 {
            tau = ctl.control(t, &s.q, &s.qd);
        }
        step_semi_implicit_ws(robot, &mut ws, &mut qdd, &mut s, &tau, None, cfg.dt);
        let ee = ee_position(robot, &s.q);
        log.t.push(t);
        log.q.push(s.q.clone());
        log.tau.push(tau.clone());
        log.ee.push(ee.0);
    }
    log
}

/// Paired-run metrics: the quantization-induced deviation between a
/// float-controlled and a quantized-controlled closed loop.
#[derive(Debug, Clone)]
pub struct PairMetrics {
    /// Max / mean end-effector deviation between the two runs [m].
    pub traj_err_max: f64,
    pub traj_err_mean: f64,
    /// Max / mean torque-vector norm difference.
    pub torque_diff_max: f64,
    pub torque_diff_mean: f64,
    /// Per-step EE deviation series (Fig. 9(b)).
    pub ee_diff: Vec<f64>,
    /// Per-step joint-space posture difference norm (Fig. 9(a)).
    pub posture_diff: Vec<f64>,
    /// Per-step torque-difference norm (Fig. 8(b)).
    pub torque_diff: Vec<f64>,
}

pub fn compare_runs(a: &RunLog, b: &RunLog) -> PairMetrics {
    let steps = a.t.len().min(b.t.len());
    let mut ee_diff = Vec::with_capacity(steps);
    let mut posture_diff = Vec::with_capacity(steps);
    let mut torque_diff = Vec::with_capacity(steps);
    for k in 0..steps {
        let de: f64 = (0..3)
            .map(|i| (a.ee[k][i] - b.ee[k][i]).powi(2))
            .sum::<f64>()
            .sqrt();
        ee_diff.push(de);
        let dq: f64 = a.q[k]
            .iter()
            .zip(&b.q[k])
            .map(|(x, y)| (x - y).powi(2))
            .sum::<f64>()
            .sqrt();
        posture_diff.push(dq);
        let dtau: f64 = a.tau[k]
            .iter()
            .zip(&b.tau[k])
            .map(|(x, y)| (x - y).powi(2))
            .sum::<f64>()
            .sqrt();
        torque_diff.push(dtau);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let maxv = |v: &[f64]| v.iter().fold(0.0f64, |m, &x| m.max(x));
    PairMetrics {
        traj_err_max: maxv(&ee_diff),
        traj_err_mean: mean(&ee_diff),
        torque_diff_max: maxv(&torque_diff),
        torque_diff_mean: mean(&torque_diff),
        ee_diff,
        posture_diff,
        torque_diff,
    }
}

/// The core ICMS evaluation: paired float/quantized closed loops.
pub fn evaluate_quantization(robot: &Robot, cfg: &IcmsConfig, fmt: QFormat) -> PairMetrics {
    let float_run = run_closed_loop(robot, cfg, RbdBackend::Exact);
    let quant_run = run_closed_loop(robot, cfg, RbdBackend::Quantized(fmt));
    compare_runs(&float_run, &quant_run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    fn small_cfg(robot: &Robot, kind: ControllerKind) -> IcmsConfig {
        let mut cfg = IcmsConfig::default_for(robot, kind);
        cfg.steps = 400;
        cfg
    }

    #[test]
    fn identical_backends_identical_runs() {
        let robot = builtin::iiwa();
        let cfg = small_cfg(&robot, ControllerKind::Pid);
        let a = run_closed_loop(&robot, &cfg, RbdBackend::Exact);
        let b = run_closed_loop(&robot, &cfg, RbdBackend::Exact);
        let m = compare_runs(&a, &b);
        assert_eq!(m.traj_err_max, 0.0, "deterministic simulation");
    }

    #[test]
    fn fine_quantization_small_deviation_pid() {
        let robot = builtin::iiwa();
        let cfg = small_cfg(&robot, ControllerKind::Pid);
        let m = evaluate_quantization(&robot, &cfg, QFormat::new(14, 18));
        assert!(
            m.traj_err_max < 5e-4,
            "32-bit-grade quantization must stay sub-0.5mm: {}",
            m.traj_err_max
        );
    }

    #[test]
    fn coarse_quantization_larger_deviation_than_fine() {
        let robot = builtin::iiwa();
        let cfg = small_cfg(&robot, ControllerKind::Pid);
        let fine = evaluate_quantization(&robot, &cfg, QFormat::new(12, 16));
        let coarse = evaluate_quantization(&robot, &cfg, QFormat::new(12, 6));
        assert!(
            coarse.traj_err_max > fine.traj_err_max,
            "coarse {} vs fine {}",
            coarse.traj_err_max,
            fine.traj_err_max
        );
    }

    #[test]
    fn closed_loop_stays_bounded() {
        let robot = builtin::iiwa();
        let cfg = small_cfg(&robot, ControllerKind::Pid);
        let run = run_closed_loop(&robot, &cfg, RbdBackend::Quantized(QFormat::new(12, 10)));
        for q in &run.q {
            for (i, x) in q.iter().enumerate() {
                assert!(x.is_finite() && x.abs() < 10.0, "joint {i} diverged: {x}");
            }
        }
    }
}
