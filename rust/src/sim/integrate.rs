//! Motion simulator integrators (the Pinocchio-backed "Motion Simulator"
//! box of Fig. 4): exact f64 forward dynamics + time stepping.

use crate::dynamics::{aba, DynWorkspace};
use crate::model::{Robot, State};
use crate::spatial::SV;

/// The shared update rule: q̇ += q̈ dt, then q += q̇ dt (symplectic order).
/// Public so serving engines that compute q̈ themselves (e.g. the
/// quantized native backend's fixed-point FD) can reuse the exact same
/// stepping as [`step_semi_implicit_ws`] when unrolling trajectory
/// requests.
pub fn semi_implicit_update(state: &mut State, qdd: &[f64], dt: f64) {
    for i in 0..qdd.len() {
        state.qd[i] += qdd[i] * dt;
        state.q[i] += state.qd[i] * dt;
    }
}

/// One semi-implicit (symplectic) Euler step: q̇ += q̈ dt, then q += q̇ dt.
/// The standard choice for control-rate physics stepping.
pub fn step_semi_implicit(
    robot: &Robot,
    state: &mut State,
    tau: &[f64],
    fext: Option<&[SV]>,
    dt: f64,
) {
    let qdd = aba(robot, &state.q, &state.qd, tau, fext);
    semi_implicit_update(state, &qdd, dt);
}

/// Allocation-free variant of [`step_semi_implicit`] for tight physics
/// loops (the ICMS fast path): the ABA sweeps run inside a caller-owned
/// [`DynWorkspace`], and `qdd` is scratch for the accelerations.
pub fn step_semi_implicit_ws(
    robot: &Robot,
    ws: &mut DynWorkspace,
    qdd: &mut [f64],
    state: &mut State,
    tau: &[f64],
    fext: Option<&[SV]>,
    dt: f64,
) {
    ws.aba_into(robot, &state.q, &state.qd, tau, fext, qdd);
    semi_implicit_update(state, qdd, dt);
}

/// Classic RK4 step on the full state (higher accuracy; used for energy
/// validation tests and fine-grained reference runs).
pub fn step_rk4(robot: &Robot, state: &mut State, tau: &[f64], dt: f64) {
    let n = robot.dof();
    let eval = |q: &[f64], qd: &[f64]| -> (Vec<f64>, Vec<f64>) {
        (qd.to_vec(), aba(robot, q, qd, tau, None))
    };
    let (k1q, k1v) = eval(&state.q, &state.qd);
    let add = |a: &[f64], b: &[f64], s: f64| -> Vec<f64> {
        a.iter().zip(b).map(|(x, y)| x + s * y).collect()
    };
    let (k2q, k2v) = eval(&add(&state.q, &k1q, dt / 2.0), &add(&state.qd, &k1v, dt / 2.0));
    let (k3q, k3v) = eval(&add(&state.q, &k2q, dt / 2.0), &add(&state.qd, &k2v, dt / 2.0));
    let (k4q, k4v) = eval(&add(&state.q, &k3q, dt), &add(&state.qd, &k3v, dt));
    for i in 0..n {
        state.q[i] += dt / 6.0 * (k1q[i] + 2.0 * k2q[i] + 2.0 * k3q[i] + k4q[i]);
        state.qd[i] += dt / 6.0 * (k1v[i] + 2.0 * k2v[i] + 2.0 * k3v[i] + k4v[i]);
    }
}

/// Total mechanical energy (kinetic + gravitational potential), for
/// integrator validation.
pub fn total_energy(robot: &Robot, state: &State) -> f64 {
    let kin = crate::dynamics::Kin::new(robot, &state.q, &state.qd);
    let n = robot.dof();
    let t: f64 = (0..n).map(|i| robot.links[i].inertia.kinetic_energy(&kin.v[i])).sum();
    // Potential: m g·h of each link CoM in world frame.
    let xw = crate::sim::fk::world_xforms(robot, &state.q);
    let mut v = 0.0;
    for i in 0..n {
        // Point convention for Xform{e, r} (A→B): p_B = e·(p_A − r), so a
        // link-frame point maps to world as p_A = eᵀ·p_B + r.
        let com_world = xw[i].e.tmul_v(&robot.links[i].inertia.com) + xw[i].r;
        v -= robot.links[i].inertia.mass * robot.gravity.dot(&com_world);
    }
    t + v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    /// RK4 at fine dt conserves energy for an unactuated pendulum swing.
    #[test]
    fn rk4_conserves_energy() {
        let robot = builtin::iiwa();
        let n = robot.dof();
        let mut s = State::zero(n);
        s.q[1] = 1.0; // swing from a raised pose
        let e0 = total_energy(&robot, &s);
        let tau = vec![0.0; n];
        for _ in 0..2000 {
            step_rk4(&robot, &mut s, &tau, 5e-4);
        }
        let e1 = total_energy(&robot, &s);
        assert!(
            (e1 - e0).abs() < 1e-3 * (1.0 + e0.abs()),
            "energy drift {e0} → {e1}"
        );
    }

    #[test]
    fn semi_implicit_stable_under_gravity() {
        let robot = builtin::iiwa();
        let n = robot.dof();
        let mut s = State::zero(n);
        s.q[1] = 0.8;
        let tau = vec![0.0; n];
        // dt chosen below the wrist links' characteristic frequency; at
        // 1 ms the unactuated chain's light wrist is marginally unstable
        // under symplectic Euler (expected for explicit integrators).
        for _ in 0..5000 {
            step_semi_implicit(&robot, &mut s, &tau, None, 2e-4);
        }
        for (i, (q, qd)) in s.q.iter().zip(&s.qd).enumerate() {
            assert!(q.is_finite() && qd.is_finite(), "joint {i} diverged");
            assert!(qd.abs() < 100.0, "joint {i} velocity blew up: {qd}");
        }
    }

    #[test]
    fn energy_decreases_never_with_zero_torque_rk4_short() {
        // Sanity on potential-energy sign: dropping from rest converts
        // potential → kinetic; total stays put, kinetic grows.
        let robot = builtin::iiwa();
        let n = robot.dof();
        let mut s = State::zero(n);
        s.q[1] = 1.2;
        let kin0: f64 = 0.0;
        let tau = vec![0.0; n];
        for _ in 0..200 {
            step_rk4(&robot, &mut s, &tau, 5e-4);
        }
        let kin = crate::dynamics::Kin::new(&robot, &s.q, &s.qd);
        let t: f64 =
            (0..n).map(|i| robot.links[i].inertia.kinetic_energy(&kin.v[i])).sum();
        assert!(t > kin0, "falling arm must gain kinetic energy");
    }
}
