//! Forward kinematics: world-frame link poses and end-effector position,
//! used for Cartesian trajectory-error metrics (the paper's motion
//! precision metric, §V-A).

use crate::model::Robot;
use crate::spatial::{V3, Xform};

/// World→link coordinate transforms for every link.
pub fn world_xforms(robot: &Robot, q: &[f64]) -> Vec<Xform> {
    let n = robot.dof();
    let mut out: Vec<Xform> = Vec::with_capacity(n);
    for i in 0..n {
        let link = &robot.links[i];
        let xup = link.joint.xform(q[i]).compose(&link.x_tree);
        let xw = match link.parent {
            Some(p) => xup.compose(&out[p]),
            None => xup,
        };
        out.push(xw);
    }
    out
}

/// Position of link `i`'s frame origin in world coordinates.
pub fn link_origin_world(robot: &Robot, q: &[f64], i: usize) -> V3 {
    // X_world→i has r = origin of link-i frame expressed in world coords.
    world_xforms(robot, q)[i].r
}

/// End-effector world position: origin of the deepest link's frame (for
/// chains this is the final joint frame; a tool offset can be applied by
/// the caller).
pub fn ee_position(robot: &Robot, q: &[f64]) -> V3 {
    let n = robot.dof();
    let deepest = (0..n).max_by_key(|&i| robot.depth(i)).unwrap_or(n - 1);
    link_origin_world(robot, q, deepest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn iiwa_home_height_is_link_sum() {
        let r = builtin::iiwa();
        let q = vec![0.0; r.dof()];
        let p = ee_position(&r, &q);
        // All joints at zero: links stack along +z.
        let want: f64 = [0.1575, 0.2025, 0.2045, 0.2155, 0.1845, 0.2155, 0.081].iter().sum();
        assert!((p.z() - want).abs() < 1e-10, "z={} want {want}", p.z());
        assert!(p.x().abs() < 1e-10 && p.y().abs() < 1e-10);
    }

    #[test]
    fn base_rotation_spins_ee_in_plane() {
        let r = builtin::iiwa();
        let mut q = vec![0.0; r.dof()];
        q[1] = 0.5; // tilt elbow so the arm leaves the z-axis
        let p0 = ee_position(&r, &q);
        q[0] = std::f64::consts::FRAC_PI_2; // rotate base 90°
        let p1 = ee_position(&r, &q);
        // Height unchanged; radius preserved.
        assert!((p0.z() - p1.z()).abs() < 1e-10);
        let r0 = (p0.x() * p0.x() + p0.y() * p0.y()).sqrt();
        let r1 = (p1.x() * p1.x() + p1.y() * p1.y()).sqrt();
        assert!((r0 - r1).abs() < 1e-10);
        assert!(r0 > 0.01, "arm must be off-axis for the test to bite");
    }

    #[test]
    fn ee_continuous_in_q() {
        let r = builtin::baxter();
        let q = vec![0.1; r.dof()];
        let p0 = ee_position(&r, &q);
        let mut q2 = q.clone();
        q2[3] += 1e-7;
        let p1 = ee_position(&r, &q2);
        assert!((p0 - p1).norm() < 1e-5);
    }
}
