//! ICMS: closed-loop control & motion simulation, forward kinematics,
//! integrators, and reference trajectories.

pub mod fk;
pub mod icms;
pub mod integrate;
pub mod traj;
