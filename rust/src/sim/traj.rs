//! Reference trajectory generation for the closed-loop evaluations: a
//! smooth, limit-respecting joint-space trajectory with analytic
//! derivatives (sin-sum per joint), plus set-point ("reach and hold")
//! references used by the PID convergence study of Fig. 9.

use crate::model::Robot;

/// A reference with analytic q(t), q̇(t), q̈(t).
#[derive(Debug, Clone)]
pub enum Trajectory {
    /// q_i(t) = center_i + amp_i · sin(ω_i t + φ_i)
    Sinusoid {
        center: Vec<f64>,
        amp: Vec<f64>,
        omega: Vec<f64>,
        phase: Vec<f64>,
    },
    /// Smooth quintic move from `from` to `to` over `duration`, then hold
    /// (the Fig. 9 "approach a target posture" workload).
    MinJerk {
        from: Vec<f64>,
        to: Vec<f64>,
        duration: f64,
    },
}

impl Trajectory {
    /// Gentle sinusoid filling a fraction of each joint's range.
    pub fn gentle_sinusoid(robot: &Robot, scale: f64, base_omega: f64) -> Trajectory {
        let n = robot.dof();
        let center: Vec<f64> =
            robot.links.iter().map(|l| 0.5 * (l.q_min + l.q_max)).collect();
        let amp: Vec<f64> =
            robot.links.iter().map(|l| scale * 0.5 * (l.q_max - l.q_min)).collect();
        let omega: Vec<f64> =
            (0..n).map(|i| base_omega * (1.0 + 0.13 * i as f64)).collect();
        let phase: Vec<f64> = (0..n).map(|i| 0.7 * i as f64).collect();
        Trajectory::Sinusoid { center, amp, omega, phase }
    }

    /// Reach from the range midpoint to a target offset, then hold.
    pub fn reach(robot: &Robot, offset_scale: f64, duration: f64) -> Trajectory {
        let from: Vec<f64> =
            robot.links.iter().map(|l| 0.5 * (l.q_min + l.q_max)).collect();
        let to: Vec<f64> = robot
            .links
            .iter()
            .map(|l| {
                let mid = 0.5 * (l.q_min + l.q_max);
                mid + offset_scale * 0.5 * (l.q_max - l.q_min)
            })
            .collect();
        Trajectory::MinJerk { from, to, duration }
    }

    pub fn dof(&self) -> usize {
        match self {
            Trajectory::Sinusoid { center, .. } => center.len(),
            Trajectory::MinJerk { from, .. } => from.len(),
        }
    }

    /// (q_ref, q̇_ref, q̈_ref) at time t.
    pub fn sample(&self, t: f64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        match self {
            Trajectory::Sinusoid { center, amp, omega, phase } => {
                let n = center.len();
                let mut q = vec![0.0; n];
                let mut qd = vec![0.0; n];
                let mut qdd = vec![0.0; n];
                for i in 0..n {
                    let th = omega[i] * t + phase[i];
                    q[i] = center[i] + amp[i] * th.sin();
                    qd[i] = amp[i] * omega[i] * th.cos();
                    qdd[i] = -amp[i] * omega[i] * omega[i] * th.sin();
                }
                (q, qd, qdd)
            }
            Trajectory::MinJerk { from, to, duration } => {
                let n = from.len();
                let s = (t / duration).clamp(0.0, 1.0);
                // Quintic min-jerk blend: 10s³ − 15s⁴ + 6s⁵.
                let b = 10.0 * s.powi(3) - 15.0 * s.powi(4) + 6.0 * s.powi(5);
                let db = (30.0 * s.powi(2) - 60.0 * s.powi(3) + 30.0 * s.powi(4)) / duration;
                let ddb =
                    (60.0 * s - 180.0 * s.powi(2) + 120.0 * s.powi(3)) / (duration * duration);
                let (db, ddb) = if t >= *duration { (0.0, 0.0) } else { (db, ddb) };
                let mut q = vec![0.0; n];
                let mut qd = vec![0.0; n];
                let mut qdd = vec![0.0; n];
                for i in 0..n {
                    let d = to[i] - from[i];
                    q[i] = from[i] + d * b;
                    qd[i] = d * db;
                    qdd[i] = d * ddb;
                }
                (q, qd, qdd)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn sinusoid_within_limits() {
        let r = builtin::iiwa();
        let traj = Trajectory::gentle_sinusoid(&r, 0.5, 1.0);
        for k in 0..200 {
            let (q, _, _) = traj.sample(k as f64 * 0.05);
            for (i, l) in r.links.iter().enumerate() {
                assert!(q[i] >= l.q_min - 1e-9 && q[i] <= l.q_max + 1e-9);
            }
        }
    }

    #[test]
    fn derivatives_consistent() {
        let r = builtin::iiwa();
        for traj in [
            Trajectory::gentle_sinusoid(&r, 0.4, 1.3),
            Trajectory::reach(&r, 0.6, 2.0),
        ] {
            let h = 1e-6;
            for t in [0.3, 0.9, 1.7] {
                let (_, qd, qdd) = traj.sample(t);
                let (qp, vp, _) = traj.sample(t + h);
                let (qm, vm, _) = traj.sample(t - h);
                for i in 0..traj.dof() {
                    let fd_v = (qp[i] - qm[i]) / (2.0 * h);
                    let fd_a = (vp[i] - vm[i]) / (2.0 * h);
                    assert!((fd_v - qd[i]).abs() < 1e-5, "q̇ mismatch");
                    assert!((fd_a - qdd[i]).abs() < 1e-4, "q̈ mismatch");
                }
            }
        }
    }

    #[test]
    fn minjerk_reaches_and_holds() {
        let r = builtin::iiwa();
        let traj = Trajectory::reach(&r, 0.5, 1.5);
        if let Trajectory::MinJerk { ref to, .. } = traj {
            let (q_end, qd_end, _) = traj.sample(5.0);
            for i in 0..r.dof() {
                assert!((q_end[i] - to[i]).abs() < 1e-12);
                assert_eq!(qd_end[i], 0.0);
            }
        }
    }
}
