//! Observability: end-to-end request tracing, live metrics, and
//! per-stage latency attribution for the serving stack.
//!
//! Two planes, one hub:
//!
//! * **Metrics** ([`metrics`]) are *always on*: atomic counters, gauges,
//!   and log₂-bucket histograms in a [`MetricsRegistry`], including the
//!   per-`(robot, route, class)` stage histograms ([`RouteStages`])
//!   that attribute every served request's latency to queue vs kernel
//!   vs egress. Exposed live over the wire by the `stats` JSONL route
//!   (`draco stats ADDR` renders Prometheus-style text) and folded into
//!   the `serve` / `loadgen` summaries.
//! * **Tracing** ([`span`]) is *opt-in* (`serve --trace PATH`): every
//!   coordinator job carries a [`Span`] stamped at admission, enqueue,
//!   batch formation, kernel start/end, and egress (streams stamp
//!   first/last chunk), finished with exactly one [`Terminal`], and
//!   recorded into lock-free drop-oldest rings. Drained records export
//!   as Chrome trace-event JSON for `chrome://tracing` / Perfetto.
//!
//! [`ObsHub`] owns both planes. The coordinator creates one hub per
//! instance; the disabled-tracing hot path is a single `OnceLock` load
//! returning a no-op span (budgeted by the `trace_overhead` bench row
//! at <2% of `fd_pool64`-class throughput). See docs/observability.md.

pub mod metrics;
pub mod span;

pub use metrics::{
    Counter, Gauge, HistSnapshot, Histogram, MetricsRegistry, MetricsSnapshot, RouteStages,
    StageTrio, HIST_BUCKETS,
};
pub use span::{chrome_trace_json, Span, SpanRecord, SpanRing, Terminal, TraceSink};

use crate::util::cli::Args;
use crate::util::json::Json;
use std::sync::{Arc, OnceLock};

/// Default number of span rings when tracing is enabled.
pub const TRACE_RINGS: usize = 8;
/// Default per-ring capacity when tracing is enabled.
pub const TRACE_RING_CAPACITY: usize = 8192;

/// One serving instance's observability state: the always-on metrics
/// registry plus the opt-in trace sink behind a `OnceLock` (so the
/// disabled check on the admission path is one atomic load).
#[derive(Debug, Default)]
pub struct ObsHub {
    metrics: Arc<MetricsRegistry>,
    trace: OnceLock<Arc<TraceSink>>,
}

impl ObsHub {
    /// Fresh hub with tracing disabled.
    pub fn new() -> ObsHub {
        ObsHub::default()
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Enable tracing (idempotent — the first enable wins) and return
    /// the sink.
    pub fn enable_tracing(&self, rings: usize, capacity: usize) -> Arc<TraceSink> {
        Arc::clone(self.trace.get_or_init(|| TraceSink::new(rings, capacity)))
    }

    /// The trace sink, if tracing was enabled.
    pub fn trace(&self) -> Option<&Arc<TraceSink>> {
        self.trace.get()
    }

    /// Open a span for one request — a real span when tracing is
    /// enabled, the inert [`Span::disabled`] otherwise.
    pub fn begin_span(&self, robot: &str, route: &str, class: &'static str) -> Span {
        match self.trace.get() {
            Some(sink) => sink.begin(robot, route, class),
            None => Span::disabled(),
        }
    }

    /// Resolve the per-stage histograms of one `(robot, route)`.
    pub fn route_stages(&self, robot: &str, route: &str, classes: &[&str]) -> RouteStages {
        RouteStages::new(&self.metrics, robot, route, classes)
    }

    /// Registry snapshot extended with the hub-level extras: worker-pool
    /// activity counters and, when tracing is enabled, the monotone
    /// `dropped_spans_total`.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        let (chunks, busy_us) = crate::dynamics::pool_activity();
        snap.counters.insert("pool_chunks_total".to_string(), chunks);
        snap.counters.insert("pool_busy_us_total".to_string(), busy_us);
        if let Some(sink) = self.trace.get() {
            snap.counters.insert("dropped_spans_total".to_string(), sink.dropped_spans());
        }
        snap
    }
}

/// `draco stats` — live metrics client and trace-file validator.
///
/// * `draco stats ADDR` connects to a serving `--listen` endpoint,
///   requests a `stats` frame, and renders it Prometheus-style.
/// * `draco stats --trace-file PATH` validates a `serve --trace` export:
///   parses the JSON, counts complete (`ph:"X"`) `job` spans, prints the
///   terminal breakdown, and fails (exit 1) on invalid JSON or zero
///   spans — the CI trace-smoke gate.
pub fn stats_cli(args: &Args) -> i32 {
    if let Some(path) = args.opt("trace-file") {
        return validate_trace_file(path);
    }
    let Some(addr) = args.positional.first() else {
        eprintln!("usage: draco stats ADDR | draco stats --trace-file PATH");
        return 2;
    };
    let sock: std::net::SocketAddr = match addr.parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bad address {addr}: {e}");
            return 2;
        }
    };
    let mut client = match crate::net::NetClient::connect(sock) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect {addr}: {e}");
            return 1;
        }
    };
    if let Err(e) = client.send_line(&crate::net::frame::stats_req_line(1)) {
        eprintln!("send stats request: {e}");
        return 1;
    }
    loop {
        match client.read_frame() {
            Ok(crate::net::Frame::Stats { counters, gauges, .. }) => {
                let snap = MetricsSnapshot { counters, gauges, ..MetricsSnapshot::default() };
                print!("{}", snap.render_prometheus());
                return 0;
            }
            Ok(crate::net::Frame::Err { msg, .. }) => {
                eprintln!("server error: {msg}");
                return 1;
            }
            Ok(_) => continue,
            Err(e) => {
                eprintln!("read stats frame: {e}");
                return 1;
            }
        }
    }
}

fn validate_trace_file(path: &str) -> i32 {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("read {path}: {e}");
            return 1;
        }
    };
    let parsed = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("{path} is not valid JSON: {e:?}");
            return 1;
        }
    };
    let Some(events) = parsed.get("traceEvents").and_then(|e| e.as_arr()) else {
        eprintln!("{path} has no traceEvents array");
        return 1;
    };
    let mut by_terminal: std::collections::BTreeMap<String, u64> = Default::default();
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str()) == Some("X")
            && ev.get("name").and_then(|n| n.as_str()) == Some("job")
        {
            let term = ev
                .get("args")
                .and_then(|a| a.get("terminal"))
                .and_then(|t| t.as_str())
                .unwrap_or("unknown");
            *by_terminal.entry(term.to_string()).or_insert(0) += 1;
        }
    }
    let total: u64 = by_terminal.values().sum();
    let breakdown: Vec<String> =
        by_terminal.iter().map(|(k, v)| format!("{k} {v}")).collect();
    println!("trace ok: {total} complete spans ({})", breakdown.join(", "));
    if total == 0 {
        eprintln!("{path} contains no complete job spans");
        return 1;
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_spans_are_disabled_until_enabled() {
        let hub = ObsHub::new();
        assert!(!hub.begin_span("iiwa", "fd", "bulk").is_enabled());
        assert!(hub.trace().is_none());
        hub.enable_tracing(2, 64);
        let mut s = hub.begin_span("iiwa", "fd", "bulk");
        assert!(s.is_enabled());
        s.finish(Terminal::Done);
        let recs = hub.trace().unwrap().drain();
        assert_eq!(recs.len(), 1);
    }

    #[test]
    fn hub_snapshot_includes_pool_and_trace_extras() {
        let hub = ObsHub::new();
        hub.metrics().counter("x_total").inc();
        let snap = hub.snapshot();
        assert_eq!(snap.counters["x_total"], 1);
        assert!(snap.counters.contains_key("pool_chunks_total"));
        assert!(snap.counters.contains_key("pool_busy_us_total"));
        assert!(!snap.counters.contains_key("dropped_spans_total"));
        hub.enable_tracing(1, 8);
        assert!(hub.snapshot().counters.contains_key("dropped_spans_total"));
    }
}
