//! Request spans, lock-free span rings, and Chrome-trace export.
//!
//! A [`Span`] rides inside every coordinator `Job`. When tracing is
//! disabled (the default) a span is `None` in an `Option<Box<…>>` —
//! every stamp is a null check, so the serving hot path pays nothing
//! measurable (the `trace_overhead` bench row keeps this honest). When
//! `serve --trace PATH` enables a [`TraceSink`], each span carries its
//! stage timestamps (admission, enqueue, batch formation, kernel
//! start/end, first/last chunk, end) on a shared µs clock and, on
//! [`Span::finish`], pushes a completed [`SpanRecord`] into one of the
//! sink's [`SpanRing`]s.
//!
//! Rings are multi-producer **drop-oldest**: a push claims a slot with a
//! `fetch_add` and swaps its record pointer in; a non-null pointer
//! swapped *out* is a dropped (overwritten) span, counted in the
//! monotone `dropped_spans` counter. Producers never block and never
//! take a lock; the collector drains by swapping slots back to null.
//! Each OS thread is assigned one ring round-robin on first push, so
//! route workers do not contend on a shared head.
//!
//! [`chrome_trace_json`] renders drained records as Chrome trace-event
//! JSON (`chrome://tracing` / [Perfetto](https://ui.perfetto.dev)): one
//! `"job"` complete event per span plus per-stage `queue` / `kernel` /
//! `egress` / `stream` slices, grouped on one track per
//! `(robot, route, class)`.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// How a span ended. Every admitted (or refused) request gets exactly
/// one terminal; a span dropped without an explicit finish records
/// [`Terminal::Abandoned`] from its `Drop` impl so the invariant holds
/// even on bug paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Terminal {
    /// Executed and answered successfully.
    Done,
    /// Refused at admission: class queue full.
    Rejected,
    /// Refused at admission: circuit breaker open.
    Shed,
    /// Dropped at batch formation: deadline passed while queued.
    Expired,
    /// Dropped: consumer disconnected before/while execution.
    Cancelled,
    /// Failed in the engine (error or caught panic) or malformed.
    Error,
    /// Failed because the coordinator was shutting down.
    Shutdown,
    /// Span dropped without an explicit terminal (bug backstop).
    Abandoned,
}

impl Terminal {
    /// Lower-case label used in trace events and summaries.
    pub fn label(self) -> &'static str {
        match self {
            Terminal::Done => "done",
            Terminal::Rejected => "rejected",
            Terminal::Shed => "shed",
            Terminal::Expired => "expired",
            Terminal::Cancelled => "cancelled",
            Terminal::Error => "error",
            Terminal::Shutdown => "shutdown",
            Terminal::Abandoned => "abandoned",
        }
    }
}

/// One completed request span: identity, stage timestamps on the
/// sink's µs clock (`None` = the request never reached that stage), and
/// the terminal outcome.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// Robot the request targeted.
    pub robot: Arc<str>,
    /// Route label (`rnea` / `fd` / `minv` / `dynall` / `traj`).
    pub route: Arc<str>,
    /// QoS class name.
    pub class: &'static str,
    /// Admission decision [µs since sink epoch]. Always present.
    pub t_admit_us: u64,
    /// Enqueued to the route worker.
    pub t_enqueue_us: Option<u64>,
    /// Picked into a batch at formation.
    pub t_formed_us: Option<u64>,
    /// Kernel execution began.
    pub t_kernel_start_us: Option<u64>,
    /// Kernel execution ended.
    pub t_kernel_end_us: Option<u64>,
    /// First response chunk written (streaming responses).
    pub t_first_chunk_us: Option<u64>,
    /// Last response chunk written (streaming responses).
    pub t_last_chunk_us: Option<u64>,
    /// Terminal stamp [µs since sink epoch]. Always present.
    pub t_end_us: u64,
    /// How the request ended.
    pub terminal: Terminal,
}

/// Lock-free multi-producer drop-oldest ring of span records.
#[derive(Debug)]
pub struct SpanRing {
    slots: Vec<AtomicPtr<SpanRecord>>,
    head: AtomicUsize,
    dropped: AtomicU64,
}

impl SpanRing {
    /// Ring holding at most `capacity` records (min 1).
    pub fn new(capacity: usize) -> SpanRing {
        SpanRing {
            slots: (0..capacity.max(1)).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
            head: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Push one record; if the claimed slot still holds an undrained
    /// record, that older record is dropped (freed) and counted.
    pub fn push(&self, rec: Box<SpanRecord>) {
        let i = self.head.fetch_add(1, Ordering::Relaxed) % self.slots.len();
        let old = self.slots[i].swap(Box::into_raw(rec), Ordering::AcqRel);
        if !old.is_null() {
            // SAFETY: a non-null pointer swapped out of a slot is owned
            // exclusively by this call — push and drain both take
            // ownership via `swap`, so no other thread can see it again.
            drop(unsafe { Box::from_raw(old) });
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Take every undrained record out of the ring (unordered).
    pub fn drain(&self) -> Vec<SpanRecord> {
        let mut out = Vec::new();
        for slot in &self.slots {
            let p = slot.swap(std::ptr::null_mut(), Ordering::AcqRel);
            if !p.is_null() {
                // SAFETY: as in `push` — `swap` transfers sole ownership.
                out.push(*unsafe { Box::from_raw(p) });
            }
        }
        out
    }

    /// Monotone count of records overwritten before being drained.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

impl Drop for SpanRing {
    fn drop(&mut self) {
        for slot in &self.slots {
            let p = slot.swap(std::ptr::null_mut(), Ordering::AcqRel);
            if !p.is_null() {
                // SAFETY: sole ownership via `swap`, as in `push`.
                drop(unsafe { Box::from_raw(p) });
            }
        }
    }
}

/// Round-robin ring assignment: each OS thread picks one ring index on
/// its first push and keeps it, so producers on different worker
/// threads land on different rings.
fn ring_index(rings: usize) -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SLOT: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    SLOT.with(|s| {
        if s.get() == usize::MAX {
            s.set(NEXT.fetch_add(1, Ordering::Relaxed));
        }
        s.get() % rings
    })
}

/// The tracing backend: a shared µs clock plus per-thread span rings.
/// Created only when tracing is enabled; the hot path reaches it through
/// one `OnceLock` load in `ObsHub`.
#[derive(Debug)]
pub struct TraceSink {
    epoch: Instant,
    rings: Vec<SpanRing>,
}

impl TraceSink {
    /// Sink with `rings` rings of `capacity` records each.
    pub fn new(rings: usize, capacity: usize) -> Arc<TraceSink> {
        Arc::new(TraceSink {
            epoch: Instant::now(),
            rings: (0..rings.max(1)).map(|_| SpanRing::new(capacity)).collect(),
        })
    }

    /// Microseconds since this sink was created (the trace time base).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Open a span stamped at admission.
    pub fn begin(self: &Arc<TraceSink>, robot: &str, route: &str, class: &'static str) -> Span {
        let rec = SpanRecord {
            robot: Arc::from(robot),
            route: Arc::from(route),
            class,
            t_admit_us: self.now_us(),
            t_enqueue_us: None,
            t_formed_us: None,
            t_kernel_start_us: None,
            t_kernel_end_us: None,
            t_first_chunk_us: None,
            t_last_chunk_us: None,
            t_end_us: 0,
            terminal: Terminal::Abandoned,
        };
        Span(Some(Box::new(ActiveSpan { sink: Arc::clone(self), rec })))
    }

    fn push(&self, rec: Box<SpanRecord>) {
        self.rings[ring_index(self.rings.len())].push(rec);
    }

    /// Drain every ring, returning records sorted by admission time.
    pub fn drain(&self) -> Vec<SpanRecord> {
        let mut out: Vec<SpanRecord> = self.rings.iter().flat_map(|r| r.drain()).collect();
        out.sort_by_key(|r| (r.t_admit_us, r.t_end_us));
        out
    }

    /// Monotone total of spans overwritten before being drained.
    pub fn dropped_spans(&self) -> u64 {
        self.rings.iter().map(|r| r.dropped()).sum()
    }
}

#[derive(Debug)]
struct ActiveSpan {
    sink: Arc<TraceSink>,
    rec: SpanRecord,
}

/// Per-request span handle. Disabled spans (`Span::disabled`) make every
/// stamp a branch on `None`; enabled spans write timestamps into their
/// record and push it to the sink on [`Span::finish`].
#[derive(Debug, Default)]
pub struct Span(Option<Box<ActiveSpan>>);

impl Span {
    /// The no-op span used when tracing is off.
    pub fn disabled() -> Span {
        Span(None)
    }

    /// Whether this span records anything.
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    fn stamp(&mut self, f: impl FnOnce(&mut SpanRecord, u64)) {
        if let Some(a) = self.0.as_mut() {
            let now = a.sink.now_us();
            f(&mut a.rec, now);
        }
    }

    /// The job entered its route queue.
    pub fn stamp_enqueue(&mut self) {
        self.stamp(|r, t| r.t_enqueue_us = Some(t));
    }

    /// The job was picked into a batch.
    pub fn stamp_formed(&mut self) {
        self.stamp(|r, t| r.t_formed_us = Some(t));
    }

    /// Kernel execution is starting for the job's batch.
    pub fn stamp_kernel_start(&mut self) {
        self.stamp(|r, t| r.t_kernel_start_us = Some(t));
    }

    /// Kernel execution finished for the job's batch.
    pub fn stamp_kernel_end(&mut self) {
        self.stamp(|r, t| r.t_kernel_end_us = Some(t));
    }

    /// A response chunk was written (first call sets the first-chunk
    /// stamp; every call advances the last-chunk stamp).
    pub fn stamp_chunk(&mut self) {
        self.stamp(|r, t| {
            if r.t_first_chunk_us.is_none() {
                r.t_first_chunk_us = Some(t);
            }
            r.t_last_chunk_us = Some(t);
        });
    }

    /// Record the terminal stamp and hand the completed record to the
    /// sink. Idempotent: the first call wins, later calls (and the
    /// `Drop` backstop) are no-ops.
    pub fn finish(&mut self, terminal: Terminal) {
        if let Some(a) = self.0.take() {
            let ActiveSpan { sink, mut rec } = *a;
            rec.t_end_us = sink.now_us();
            rec.terminal = terminal;
            sink.push(Box::new(rec));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        // Backstop: a span dropped without a terminal still records one,
        // so "every admitted job ends in exactly one terminal" holds.
        self.finish(Terminal::Abandoned);
    }
}

/// Render drained records as Chrome trace-event JSON. One `"job"`
/// complete event (`ph:"X"`) spans admission → end with the terminal in
/// its args; `queue` / `kernel` / `egress` / `stream` slices attribute
/// the inside. Tracks (`tid`) are one per `(robot, route, class)`, named
/// via `thread_name` metadata events.
pub fn chrome_trace_json(records: &[SpanRecord]) -> String {
    let mut tids: BTreeMap<(String, String, &'static str), u64> = BTreeMap::new();
    let mut events: Vec<Json> = Vec::new();
    for r in records {
        let key = (r.robot.to_string(), r.route.to_string(), r.class);
        let next = tids.len() as u64 + 1;
        let tid = *tids.entry(key).or_insert(next);
        let slice = |name: &str, ts: u64, end: u64, events: &mut Vec<Json>| {
            events.push(json::obj(vec![
                ("cat", json::s("stage")),
                ("dur", json::num(end.saturating_sub(ts) as f64)),
                ("name", json::s(name)),
                ("ph", json::s("X")),
                ("pid", json::num(1.0)),
                ("tid", json::num(tid as f64)),
                ("ts", json::num(ts as f64)),
            ]));
        };
        events.push(json::obj(vec![
            (
                "args",
                json::obj(vec![
                    ("class", json::s(r.class)),
                    ("robot", json::s(&r.robot)),
                    ("route", json::s(&r.route)),
                    ("terminal", json::s(r.terminal.label())),
                ]),
            ),
            ("cat", json::s("request")),
            ("dur", json::num(r.t_end_us.saturating_sub(r.t_admit_us) as f64)),
            ("name", json::s("job")),
            ("ph", json::s("X")),
            ("pid", json::num(1.0)),
            ("tid", json::num(tid as f64)),
            ("ts", json::num(r.t_admit_us as f64)),
        ]));
        if let (Some(enq), Some(formed)) = (r.t_enqueue_us, r.t_formed_us) {
            slice("queue", enq, formed, &mut events);
        }
        if let (Some(ks), Some(ke)) = (r.t_kernel_start_us, r.t_kernel_end_us) {
            slice("kernel", ks, ke, &mut events);
            slice("egress", ke, r.t_end_us, &mut events);
        }
        if let (Some(first), Some(last)) = (r.t_first_chunk_us, r.t_last_chunk_us) {
            slice("stream", first, last, &mut events);
        }
    }
    for ((robot, route, class), tid) in &tids {
        events.push(json::obj(vec![
            ("args", json::obj(vec![("name", json::s(&format!("{robot}/{route} [{class}]")))])),
            ("name", json::s("thread_name")),
            ("ph", json::s("M")),
            ("pid", json::num(1.0)),
            ("tid", json::num(*tid as f64)),
        ]));
    }
    json::obj(vec![
        ("displayTimeUnit", json::s("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
    .pretty()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finished(sink: &Arc<TraceSink>, terminal: Terminal) {
        let mut s = sink.begin("iiwa", "fd", "interactive");
        s.stamp_enqueue();
        s.stamp_formed();
        s.stamp_kernel_start();
        s.stamp_kernel_end();
        s.finish(terminal);
    }

    #[test]
    fn span_records_every_stamp_and_one_terminal() {
        let sink = TraceSink::new(2, 16);
        finished(&sink, Terminal::Done);
        let recs = sink.drain();
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!(r.terminal, Terminal::Done);
        assert!(r.t_enqueue_us.is_some());
        assert!(r.t_formed_us.is_some());
        assert!(r.t_kernel_start_us.unwrap() <= r.t_kernel_end_us.unwrap());
        assert!(r.t_end_us >= r.t_admit_us);
        // Finish is idempotent and drain is destructive.
        assert!(sink.drain().is_empty());
    }

    #[test]
    fn dropped_span_records_abandoned_terminal() {
        let sink = TraceSink::new(1, 8);
        {
            let mut s = sink.begin("iiwa", "traj", "bulk");
            s.stamp_chunk();
            s.stamp_chunk();
            // dropped without finish
        }
        let recs = sink.drain();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].terminal, Terminal::Abandoned);
        assert!(recs[0].t_first_chunk_us.is_some());
        assert!(recs[0].t_last_chunk_us >= recs[0].t_first_chunk_us);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let ring = SpanRing::new(4);
        let sink = TraceSink::new(1, 4);
        for k in 0..10u64 {
            let mut s = sink.begin("iiwa", "fd", "bulk");
            s.finish(Terminal::Done);
            // Also exercise the raw ring directly with distinguishable
            // admission stamps.
            ring.push(Box::new(SpanRecord {
                robot: Arc::from("iiwa"),
                route: Arc::from("fd"),
                class: "bulk",
                t_admit_us: k,
                t_enqueue_us: None,
                t_formed_us: None,
                t_kernel_start_us: None,
                t_kernel_end_us: None,
                t_first_chunk_us: None,
                t_last_chunk_us: None,
                t_end_us: k,
                terminal: Terminal::Done,
            }));
        }
        assert_eq!(ring.dropped(), 6);
        let recs = ring.drain();
        assert_eq!(recs.len(), 4);
        // The survivors are the newest four pushes.
        let mut stamps: Vec<u64> = recs.iter().map(|r| r.t_admit_us).collect();
        stamps.sort_unstable();
        assert_eq!(stamps, vec![6, 7, 8, 9]);
        // The sink's rings overflowed too (capacity 4, 10 finishes).
        assert_eq!(sink.dropped_spans(), 6);
        assert_eq!(sink.drain().len(), 4);
    }

    #[test]
    fn disabled_span_is_inert() {
        let mut s = Span::disabled();
        assert!(!s.is_enabled());
        s.stamp_enqueue();
        s.stamp_kernel_start();
        s.finish(Terminal::Done);
    }

    #[test]
    fn chrome_trace_is_valid_json_with_job_events() {
        let sink = TraceSink::new(2, 16);
        finished(&sink, Terminal::Done);
        finished(&sink, Terminal::Expired);
        let recs = sink.drain();
        let text = chrome_trace_json(&recs);
        let parsed = Json::parse(&text).expect("valid trace json");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).expect("events");
        let jobs: Vec<&Json> = events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(|p| p.as_str()) == Some("X")
                    && e.get("name").and_then(|n| n.as_str()) == Some("job")
            })
            .collect();
        assert_eq!(jobs.len(), 2);
        let terminals: Vec<&str> = jobs
            .iter()
            .filter_map(|e| e.get("args")?.get("terminal")?.as_str())
            .collect();
        assert!(terminals.contains(&"done") && terminals.contains(&"expired"));
        assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M")));
    }
}
