//! Atomic metrics primitives and the process-wide registry.
//!
//! Three shapes cover the serving stack's needs:
//!
//! * [`Counter`] — monotone `u64` (requests, errors, kills).
//! * [`Gauge`] — last-or-max `u64` (queue-depth high-water).
//! * [`Histogram`] — fixed log₂ buckets over µs values; lock-free
//!   `record`, percentile estimates from the bucket bounds with linear
//!   interpolation inside the landing bucket.
//!
//! All three are plain `AtomicU64`s with relaxed ordering: a `record` on
//! the serving hot path is one or three uncontended `fetch_add`s, cheap
//! enough to stay **always on** (the `trace_overhead` bench row tracks
//! the budget). Handles are `Arc`s resolved once at registration — the
//! hot path never does a name lookup.
//!
//! [`MetricsRegistry`] is the name → handle map (get-or-register,
//! poison-recovering locks); [`MetricsSnapshot`] is its point-in-time
//! copy, renderable as Prometheus-style text and serialized over the
//! wire by the `stats` route (`net::frame`). Metric names follow the
//! Prometheus idiom — `snake_case` with a `_total` suffix for counters
//! and an optional `{key="value",…}` label block sorted by key (see
//! docs/observability.md for the full reference).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log₂ buckets in a [`Histogram`]: bucket `i ≥ 1` holds
/// values in `[2^(i-1), 2^i)` µs, bucket 0 holds zeros; the last bucket
/// absorbs everything from `2^38` µs (~3.2 days) up.
pub const HIST_BUCKETS: usize = 40;

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Fresh counter at zero.
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value / high-water gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Fresh gauge at zero.
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the value to `v` if `v` is larger (high-water tracking).
    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed log₂-bucket histogram over `u64` values (µs by convention).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// Bucket index of `v`: 0 for zero, else `floor(log2 v) + 1`, capped to
/// the last bucket — so bucket `i ≥ 1` covers `[2^(i-1), 2^i)`.
fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// Fresh histogram with every bucket at zero.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value (three relaxed `fetch_add`s).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the bucket counts.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count(),
            sum: self.sum(),
        }
    }
}

/// Point-in-time copy of one [`Histogram`].
#[derive(Debug, Clone, Default)]
pub struct HistSnapshot {
    /// Per-bucket counts ([`HIST_BUCKETS`] entries).
    pub buckets: Vec<u64>,
    /// Total recorded values.
    pub count: u64,
    /// Sum of recorded values.
    pub sum: u64,
}

impl HistSnapshot {
    /// Nearest-rank percentile estimate (`p` in `[0, 1]`), linearly
    /// interpolated inside the landing bucket. Zero when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                if i == 0 {
                    return 0.0; // bucket 0 holds exactly the zeros
                }
                let lo = (1u64 << (i - 1)) as f64;
                let hi = (1u64 << i) as f64;
                let frac = (rank - seen) as f64 / c as f64;
                return lo + (hi - lo) * frac;
            }
            seen += c;
        }
        (1u64 << (HIST_BUCKETS - 1)) as f64
    }

    /// Mean of recorded values. Zero when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Name → handle registry. Registration is get-or-create (two callers
/// asking for the same name share one atomic); the returned `Arc` is the
/// hot-path handle, so lookups happen once at setup, never per event.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Get-or-register the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            lock(&self.counters).entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new())),
        )
    }

    /// Get-or-register the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            lock(&self.gauges).entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new())),
        )
    }

    /// Get-or-register the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            lock(&self.hists).entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    /// Point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: lock(&self.counters).iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            gauges: lock(&self.gauges).iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            hists: lock(&self.hists).iter().map(|(k, v)| (k.clone(), v.snapshot())).collect(),
        }
    }
}

/// Split `name{labels}` into `(name, labels-without-braces)`.
fn split_labels(name: &str) -> (&str, Option<&str>) {
    match name.split_once('{') {
        Some((base, rest)) => (base, Some(rest.trim_end_matches('}'))),
        None => (name, None),
    }
}

/// Point-in-time copy of a [`MetricsRegistry`].
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge values by name.
    pub gauges: BTreeMap<String, u64>,
    /// Histogram snapshots by name.
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl MetricsSnapshot {
    /// Render as Prometheus-style exposition text: `# TYPE` headers,
    /// `name{labels} value` lines, histograms as cumulative
    /// `_bucket{le="…"}` series plus `_sum` / `_count`.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_base = String::new();
        let mut typed = |out: &mut String, name: &str, kind: &str| {
            let (base, _) = split_labels(name);
            if base != last_base {
                let _ = writeln!(out, "# TYPE {base} {kind}");
                last_base = base.to_string();
            }
        };
        for (name, v) in &self.counters {
            typed(&mut out, name, "counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            typed(&mut out, name, "gauge");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, h) in &self.hists {
            typed(&mut out, name, "histogram");
            let (base, labels) = split_labels(name);
            let tail = |le: &str| match labels {
                Some(l) => format!("{base}_bucket{{{l},le=\"{le}\"}}"),
                None => format!("{base}_bucket{{le=\"{le}\"}}"),
            };
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                cum += c;
                let hi = if i == 0 { 0 } else { 1u64 << i };
                let _ = writeln!(out, "{} {cum}", tail(&hi.to_string()));
            }
            let _ = writeln!(out, "{} {}", tail("+Inf"), h.count);
            match labels {
                Some(l) => {
                    let _ = writeln!(out, "{base}_sum{{{l}}} {}", h.sum);
                    let _ = writeln!(out, "{base}_count{{{l}}} {}", h.count);
                }
                None => {
                    let _ = writeln!(out, "{base}_sum {}", h.sum);
                    let _ = writeln!(out, "{base}_count {}", h.count);
                }
            }
        }
        out
    }
}

/// The three per-stage latency histograms of one `(robot, route, class)`:
/// time queued (admission → batch formation), time in the kernel, and
/// time in egress (kernel end → last response write).
#[derive(Debug, Clone)]
pub struct StageTrio {
    /// Queue-wait histogram [µs].
    pub queue: Arc<Histogram>,
    /// Kernel-execution histogram [µs].
    pub kernel: Arc<Histogram>,
    /// Egress-flush histogram [µs].
    pub egress: Arc<Histogram>,
}

impl StageTrio {
    fn new(m: &MetricsRegistry, labels: Option<(&str, &str, &str)>) -> StageTrio {
        let name = |stage: &str| match labels {
            Some((robot, route, class)) => format!(
                "stage_{stage}_us{{class=\"{class}\",robot=\"{robot}\",route=\"{route}\"}}"
            ),
            None => format!("stage_{stage}_us"),
        };
        StageTrio {
            queue: m.histogram(&name("queue")),
            kernel: m.histogram(&name("kernel")),
            egress: m.histogram(&name("egress")),
        }
    }
}

/// Per-route stage attribution: one labelled [`StageTrio`] per QoS class
/// (indexed by class index) plus the route-agnostic aggregate trio, the
/// batch-fill distribution, and the batch-execution distribution. One
/// `RouteStages` is resolved per `(robot, route)` at route registration;
/// every record is then index + `fetch_add`, no lookups.
#[derive(Debug, Clone)]
pub struct RouteStages {
    per_class: Vec<StageTrio>,
    all: StageTrio,
    /// Batch fill distribution [% of route batch capacity], aggregate.
    pub fill: Arc<Histogram>,
    /// Batch kernel-execution distribution [µs], aggregate.
    pub exec: Arc<Histogram>,
}

impl RouteStages {
    /// Resolve the stage histograms of `(robot, route)` for every class
    /// name in `classes` (indexed by position).
    pub fn new(m: &MetricsRegistry, robot: &str, route: &str, classes: &[&str]) -> RouteStages {
        RouteStages {
            per_class: classes
                .iter()
                .map(|class| StageTrio::new(m, Some((robot, route, class))))
                .collect(),
            all: StageTrio::new(m, None),
            fill: m.histogram("batch_fill_pct"),
            exec: m.histogram("batch_exec_us"),
        }
    }

    /// Record a queue-wait sample for class index `class`.
    pub fn record_queue(&self, class: usize, us: u64) {
        self.per_class[class].queue.record(us);
        self.all.queue.record(us);
    }

    /// Record a kernel-time sample for class index `class`.
    pub fn record_kernel(&self, class: usize, us: u64) {
        self.per_class[class].kernel.record(us);
        self.all.kernel.record(us);
    }

    /// Record an egress-flush sample for class index `class`.
    pub fn record_egress(&self, class: usize, us: u64) {
        self.per_class[class].egress.record(us);
        self.all.egress.record(us);
    }

    /// Record one executed batch: fill percentage and kernel µs.
    pub fn record_batch(&self, fill_pct: u64, exec_us: u64) {
        self.fill.record(fill_pct);
        self.exec.record(exec_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_log2() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_percentiles_bracket_samples() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        let p50 = s.percentile(0.5);
        let p99 = s.percentile(0.99);
        // Log2 buckets bound the true percentile within 2x.
        assert!((250.0..=1024.0).contains(&p50), "p50 {p50}");
        assert!((512.0..=1024.0).contains(&p99), "p99 {p99}");
        assert!(p50 <= p99);
        assert!((s.mean() - 500.5).abs() < 1.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.percentile(0.99), 0.0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn registry_shares_handles_by_name() {
        let m = MetricsRegistry::new();
        let a = m.counter("x_total");
        let b = m.counter("x_total");
        a.inc();
        b.add(2);
        assert_eq!(m.counter("x_total").get(), 3);
        m.gauge("g").record_max(7);
        m.gauge("g").record_max(3);
        assert_eq!(m.gauge("g").get(), 7);
        let snap = m.snapshot();
        assert_eq!(snap.counters["x_total"], 3);
        assert_eq!(snap.gauges["g"], 7);
    }

    #[test]
    fn prometheus_rendering_has_types_and_buckets() {
        let m = MetricsRegistry::new();
        m.counter("jobs_total").add(5);
        m.histogram("lat_us{route=\"fd\"}").record(3);
        let text = m.snapshot().render_prometheus();
        assert!(text.contains("# TYPE jobs_total counter"), "{text}");
        assert!(text.contains("jobs_total 5"), "{text}");
        assert!(text.contains("# TYPE lat_us histogram"), "{text}");
        assert!(text.contains("lat_us_bucket{route=\"fd\",le=\"4\"} 1"), "{text}");
        assert!(text.contains("lat_us_count{route=\"fd\"} 1"), "{text}");
    }

    #[test]
    fn route_stages_record_labelled_and_aggregate() {
        let m = MetricsRegistry::new();
        let rs = RouteStages::new(&m, "iiwa", "fd", &["control", "interactive", "bulk"]);
        rs.record_queue(1, 10);
        rs.record_kernel(1, 20);
        rs.record_egress(1, 5);
        rs.record_batch(50, 20);
        let snap = m.snapshot();
        assert_eq!(snap.hists["stage_queue_us"].count, 1);
        assert_eq!(
            snap.hists["stage_queue_us{class=\"interactive\",robot=\"iiwa\",route=\"fd\"}"].count,
            1
        );
        assert_eq!(snap.hists["batch_fill_pct"].count, 1);
        assert_eq!(snap.hists["batch_exec_us"].count, 1);
    }
}
