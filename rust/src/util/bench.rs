//! Timing/bench substrate (no `criterion` offline).
//!
//! Measures a closure with warmup, reports robust statistics, and prints
//! paper-style aligned tables. Used by every `rust/benches/*.rs` target
//! (all declared `harness = false`).

use std::time::Instant;

/// Statistics over one measured closure.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
    /// Tasks/second if each iteration processed `batch` tasks.
    pub fn throughput(&self, batch: usize) -> f64 {
        batch as f64 / (self.mean_ns * 1e-9)
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `iters` measured runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Stats {
        iters,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min_ns: samples[0],
    }
}

/// Auto-calibrating variant: choose an iteration count so the measured
/// region runs for roughly `target_ms` total, then report per-call stats.
pub fn time_auto<F: FnMut()>(target_ms: f64, mut f: F) -> Stats {
    // Calibrate with one run.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_ms / 1e3 / once).ceil() as usize).clamp(3, 10_000);
    time(1, iters, f)
}

/// Aligned table printer: fixed-width columns from header widths.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let line: Vec<String> = self
            .headers
            .iter()
            .zip(&self.widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        println!("{}", line.join("  "));
        println!("{}", "-".repeat(line.join("  ").len()));
        for r in &self.rows {
            let line: Vec<String> =
                r.iter().zip(&self.widths).map(|(c, w)| format!("{c:>w$}")).collect();
            println!("{}", line.join("  "));
        }
    }
}

/// Format helpers matching the paper's unit conventions.
pub fn fmt_us(ns: f64) -> String {
    format!("{:.2}", ns / 1e3)
}

pub fn fmt_ktasks(per_s: f64) -> String {
    format!("{:.1}", per_s / 1e3)
}

pub fn fmt_ratio(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_sane_stats() {
        let s = time(2, 16, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.iters, 16);
        assert!(s.min_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns + 1.0);
    }

    #[test]
    fn throughput_math() {
        let s = Stats { iters: 1, mean_ns: 1e6, median_ns: 1e6, p95_ns: 1e6, min_ns: 1e6 };
        // 1 ms per batch of 256 => 256k tasks/s
        assert!((s.throughput(256) - 256_000.0).abs() < 1.0);
    }

    #[test]
    fn table_builds() {
        let mut t = Table::new(&["robot", "lat(us)"]);
        t.row(&["iiwa".into(), "1.23".into()]);
        t.print("smoke");
    }
}
