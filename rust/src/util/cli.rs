//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        let mut rest_positional = false;
        while let Some(a) = it.next() {
            if rest_positional {
                out.positional.push(a);
                continue;
            }
            if a == "--" {
                // Everything after a bare `--` is positional.
                rest_positional = true;
                continue;
            }
            if let Some(name) = a.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: a bare `--name tok` pair is parsed as option+value; use the
        // `--` separator to force trailing positionals.
        let a = parse("serve --robot iiwa --batch 64 --verbose -- artifacts/x.hlo.txt");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt("robot"), Some("iiwa"));
        assert_eq!(a.opt_usize("batch", 1), 64);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["artifacts/x.hlo.txt"]);
    }

    #[test]
    fn key_equals_value() {
        let a = parse("bench --fn=minv --robot=atlas");
        assert_eq!(a.opt("fn"), Some("minv"));
        assert_eq!(a.opt("robot"), Some("atlas"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.opt_or("robot", "iiwa"), "iiwa");
        assert_eq!(a.opt_f64("tol", 0.5), 0.5);
    }
}
