//! Quickcheck-style property-test harness (no `proptest` offline).
//!
//! Runs a property over `cases` randomized inputs drawn from a generator
//! closure. On failure it re-seeds and performs a bounded "shrink" by
//! retrying with generators of decreasing magnitude scale, reporting the
//! smallest failing seed. Deterministic: seeds derive from the property
//! name so CI runs are reproducible.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xD1_5EA5E }
    }
}

fn name_seed(name: &str, base: u64) -> u64 {
    // FNV-1a over the property name mixed with the base seed.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ base
}

/// Run `prop` over `cases` inputs produced by `gen`. Panics (with the
/// failing case Debug-printed and its seed) on the first counterexample.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = name_seed(name, cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = Rng::new(base.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {:#x}):\n{input:#?}",
                base.wrapping_add(case as u64)
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result` so failures can carry
/// a message.
pub fn forall_res<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = name_seed(name, cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = Rng::new(base.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {:#x}): {msg}\n{input:#?}",
                base.wrapping_add(case as u64)
            );
        }
    }
}

/// Relative-or-absolute closeness used across numeric tests.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    (a - b).abs() <= tol * scale
}

/// Max elementwise |a-b| over equal-length slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Assert slices elementwise close with a scale-aware tolerance.
pub fn assert_slices_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(close(*x, *y, tol), "{what}[{i}]: {x} vs {y} (tol {tol})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        forall("always-true", Config::default(), |r| r.f64(), |x| (0.0..1.0).contains(x));
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn fails_fast() {
        forall("always-false", Config { cases: 4, ..Default::default() }, |r| r.f64(), |_| false);
    }

    #[test]
    fn close_is_scale_aware() {
        assert!(close(1e12, 1e12 + 1.0, 1e-9));
        assert!(!close(1.0, 1.1, 1e-3));
        assert!(close(0.0, 1e-10, 1e-9));
    }

    #[test]
    fn seeds_depend_on_name() {
        assert_ne!(name_seed("a", 0), name_seed("b", 0));
    }
}
