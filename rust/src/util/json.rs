//! Minimal JSON parser/serializer.
//!
//! Substrate module: the vendored crate set has no `serde`/`serde_json`
//! (offline environment), so robot descriptions, configs and result dumps
//! go through this self-contained implementation. Supports the full JSON
//! grammar (RFC 8259), including `\u` surrogate-pair decoding beyond the
//! BMP (a lone or mismatched surrogate decodes to U+FFFD).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `[f64]` convenience: parse an array of numbers.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (k, (key, v)) in o.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{}", n));
        }
    } else {
        // JSON has no Inf/NaN; serialize as null (documented lossy case).
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            if (0xD800..=0xDBFF).contains(&cp) {
                                // High surrogate: combine with an
                                // immediately following \uDC00–\uDFFF low
                                // surrogate into one supplementary-plane
                                // scalar (RFC 8259 §7). A lone or
                                // mismatched surrogate decodes to U+FFFD
                                // without consuming what follows.
                                let lo = if self.i + 10 < self.b.len()
                                    && self.b[self.i + 5] == b'\\'
                                    && self.b[self.i + 6] == b'u'
                                {
                                    std::str::from_utf8(&self.b[self.i + 7..self.i + 11])
                                        .ok()
                                        .and_then(|h| u32::from_str_radix(h, 16).ok())
                                        .filter(|lo| (0xDC00..=0xDFFF).contains(lo))
                                } else {
                                    None
                                };
                                match lo {
                                    Some(lo) => {
                                        let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                        s.push(char::from_u32(c).unwrap_or('\u{fffd}'));
                                        self.i += 10;
                                    }
                                    None => {
                                        s.push('\u{fffd}');
                                        self.i += 4;
                                    }
                                }
                            } else if (0xDC00..=0xDFFF).contains(&cp) {
                                // Unpaired low surrogate.
                                s.push('\u{fffd}');
                                self.i += 4;
                            } else {
                                s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                                self.i += 4;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, msg: format!("bad number '{txt}'") })
    }
}

/// Builder helpers so call sites stay terse.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,-3],"s":"q\"w","t":true,"z":null}"#;
        let v = Json::parse(src).unwrap();
        let d = v.dump();
        assert_eq!(Json::parse(&d).unwrap(), v);
        let p = v.pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn surrogate_pairs_decode_beyond_the_bmp() {
        // U+1F600 via its UTF-16 surrogate pair, lower- and uppercase hex.
        assert_eq!(Json::parse("\"\\ud83d\\ude00\"").unwrap(), Json::Str("\u{1f600}".into()));
        assert_eq!(
            Json::parse("\"x\\uD83D\\uDE00y\"").unwrap(),
            Json::Str("x\u{1f600}y".into())
        );
        // U+10000, the first supplementary-plane scalar.
        assert_eq!(Json::parse("\"\\ud800\\udc00\"").unwrap(), Json::Str("\u{10000}".into()));
        // Lone high (mid-string and at end-of-string), lone low, and a
        // high followed by a non-surrogate escape all decode to U+FFFD
        // without consuming the data after them.
        assert_eq!(Json::parse("\"\\ud83dZ\"").unwrap(), Json::Str("\u{fffd}Z".into()));
        assert_eq!(Json::parse("\"\\ud800\"").unwrap(), Json::Str("\u{fffd}".into()));
        assert_eq!(Json::parse("\"\\ude00\"").unwrap(), Json::Str("\u{fffd}".into()));
        assert_eq!(
            Json::parse("\"\\ud83d\\u0041\"").unwrap(),
            Json::Str("\u{fffd}A".into())
        );
    }

    #[test]
    fn escape_round_trips_astral_text() {
        // Serialized astral scalars are emitted as literal UTF-8 and must
        // survive dump → parse unchanged; parsing the escaped spelling
        // must agree with parsing the literal spelling.
        let v = Json::Str("mixed \u{1f600} \u{10abcd} text \"q\"\n".into());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::parse("\"\u{1f600}\"").unwrap()
        );
    }

    #[test]
    fn numbers_preserved() {
        for x in [0.0, 1.0, -1.0, 3.141592653589793, 1e-12, 2.5e300] {
            let v = Json::Num(x).dump();
            assert_eq!(Json::parse(&v).unwrap().as_f64().unwrap(), x, "{v}");
        }
    }

    #[test]
    fn helpers() {
        let j = obj(vec![("x", num(1.0)), ("v", arr_f64(&[1.0, 2.0]))]);
        assert_eq!(j.get("v").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.0]);
        assert_eq!(j.get("x").unwrap().as_usize(), Some(1));
    }
}
