//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! SplitMix64 for seeding + xoshiro256** as the workhorse generator —
//! both public-domain algorithms (Blackman & Vigna). Deterministic across
//! platforms, which the benchmark harness relies on for reproducible
//! workloads.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn vec_range(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.range(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
