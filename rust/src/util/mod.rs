//! Shared substrates: JSON, PRNG, property-test harness, CLI, bench timing.
//!
//! These exist because the offline environment ships no serde/clap/
//! criterion/proptest — see DESIGN.md "Substitutions".

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod rng;
