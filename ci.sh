#!/usr/bin/env bash
# Tier-1 gate + perf smoke for the draco crate. Mirrors
# .github/workflows/ci.yml so the same checks run locally.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy (all targets, -D warnings) =="
# Clippy is advisory-fatal on every target (lib, bins, benches, tests);
# keep going if clippy itself is not installed (minimal toolchains).
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy unavailable; skipping lint"
fi

echo "== cargo doc --no-deps =="
cargo doc --no-deps

echo "== docs link check =="
bash ../scripts/check_doc_links.sh

echo "== quantize --emit-spec smoke (search -> serving loop) =="
# The bit-width search must emit a ready-to-paste registry spec line:
# qint when the scaling analysis proves the chosen format, quant when
# it rejects it — either way the line parses as a --robots entry.
spec_out="$(cargo run --release --quiet -- quantize --robot iiwa --controller pid \
    --tol 5e-3 --steps 300 --emit-spec)"
echo "$spec_out" | tail -n 4
if ! printf '%s\n' "$spec_out" | grep -Eq '^iiwa:(qint|quant)@[0-9]+\.[0-9]+$'; then
    echo "EMIT-SPEC FAIL: no registry spec line in quantize output" >&2
    exit 1
fi

echo "== bench smoke: hotpath_cpu --quick =="
cargo bench --bench hotpath_cpu -- --quick

echo "== bench schema check (bench_diff --check) =="
bash ../scripts/bench_diff.sh --check BENCH_hotpath.json

echo "== serve smoke: warm dyn_all kinematics memo =="
# The serve workload ends with a cold/warm `dyn_all` probe per robot:
# the warm repeat must be bitwise identical to the cold response (serve
# exits nonzero otherwise) and, on serial routes, must be answered out
# of the kinematics memo — so the printed hit counter must be nonzero.
serve_out="$(cargo run --release --quiet -- serve --requests 64 --batch 8 --window-us 200 \
    --robots iiwa,atlas:qint@12.14)"
echo "$serve_out" | tail -n 4
hits="$(printf '%s\n' "$serve_out" | sed -n 's/^dyn_all memo: hits \([0-9]*\).*/\1/p')"
if [ -z "$hits" ] || [ "$hits" -eq 0 ]; then
    echo "MEMO SMOKE FAIL: warm dyn_all serve reported no kinematics-memo hits" >&2
    exit 1
fi

echo "== wire smoke: serve --listen + replay =="
# Bring the JSONL TCP front-end up on an ephemeral port, let the
# self-drive client push mixed routes (steps, three-segment dyn_all,
# a mid-horizon-streamed trajectory, a deadline-0 expiry, and malformed
# frames) through a real socket with --tee, then re-execute the capture
# offline: replay exits nonzero unless every comparable response is
# bitwise identical and lazy/full parsing agree on every captured line.
TEE="$(mktemp)"
TRACE="$(mktemp)"
trap 'rm -f "$TEE" "$TRACE"' EXIT
cargo run --release --quiet -- serve --requests 32 --batch 8 --window-us 200 \
    --robots iiwa,atlas:qint@12.14 --traj 16 --listen 127.0.0.1:0 --tee "$TEE"
cargo run --release --quiet -- replay "$TEE"

echo "== trace smoke: serve --trace + stats --trace-file =="
# Run a short traced workload and validate the Chrome trace-event
# export: `stats --trace-file` parses the JSON and exits nonzero unless
# it finds at least one complete job span — so an empty, truncated, or
# malformed export fails CI here.
cargo run --release --quiet -- serve --requests 32 --batch 8 --window-us 200 \
    --robots iiwa --trace "$TRACE"
cargo run --release --quiet -- stats --trace-file "$TRACE"

echo "== fault smoke: loadgen --smoke --faults =="
# Wire fault suite under a seeded FaultPlan: 4 concurrent connections
# (healthy, garbage-spraying, write-tearing, mid-stream-disconnecting)
# plus a retry client driven into a flooded lane. Exits nonzero on any
# cross-connection id bleed, stuck batch, or non-terminating retry.
cargo run --release --quiet -- loadgen --smoke --faults

echo "== overload smoke: loadgen --smoke =="
# Short open-loop ramp against a capacity-pinned route; asserts the
# overload invariants (no expired job executed, monotone shedding,
# Control-p99 bound, breaker trip/half-open/recover) and rewrites
# BENCH_serve.json, whose schema the next step validates.
cargo run --release --quiet -- loadgen --smoke

echo "== serve bench schema check (bench_diff --check) =="
bash ../scripts/bench_diff.sh --check BENCH_serve.json

echo "CI OK"
