#!/usr/bin/env bash
# Docs link check: fail if README.md or any file under docs/ contains a
# markdown link to a relative path that does not exist. External links
# (http/https/mailto) and pure anchors are skipped; anchors on relative
# links are stripped before the existence check.
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0
for f in README.md docs/*.md; do
    [ -e "$f" ] || continue
    base="$(dirname "$f")"
    # Extract markdown link targets: ](target)
    while IFS= read -r target; do
        t="${target%%#*}"   # strip anchors
        case "$t" in
            '' ) continue ;;                              # pure anchor
            http://*|https://*|mailto:* ) continue ;;     # external
        esac
        if [ ! -e "$base/$t" ]; then
            echo "BROKEN LINK in $f: ($target)"
            fail=1
        fi
    done < <(grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//')
done

if [ "$fail" -ne 0 ]; then
    echo "docs link check FAILED"
    exit 1
fi
echo "docs link check OK"
