#!/usr/bin/env bash
# Compare two BENCH_*.json files row by row, or validate one file
# against its schema. Pure bash + awk — no jq/python dependency, parses
# the pretty-printed JSON the bench/loadgen harnesses emit. Handles both
# tracked schemas:
#
#   draco.hotpath.v1  (cargo bench --bench hotpath_cpu)  rows keyed (robot, fn)
#   draco.serve.v1    (draco loadgen)                    rows keyed (scenario, class)
#
#   scripts/bench_diff.sh old.json new.json   # per-row median/p99 deltas
#   scripts/bench_diff.sh --check file.json   # schema validation (CI runs
#                                             # this on the smoke outputs)
set -euo pipefail

usage() {
    echo "usage: $0 old.json new.json | $0 --check file.json" >&2
    exit 2
}

schema_of() {
    if grep -q '"schema": "draco.serve.v1"' "$1"; then
        echo serve
    elif grep -q '"schema": "draco.hotpath.v1"' "$1"; then
        echo hotpath
    else
        echo unknown
    fi
}

# Emit "robot|fn|median_us" per bench row. Relies on the serializer's
# deterministic (BTreeMap, alphabetical) key order within each row
# object: fn, mean_us, median_us, robot, tasks_per_s — so tasks_per_s
# closes a row. The speedups array never carries tasks_per_s, so its
# objects never emit.
extract_hotpath() {
    awk '
        /"fn":/         { v = $2; gsub(/[",]/, "", v); fn = v }
        /"median_us":/  { v = $2; gsub(/[",]/, "", v); med = v }
        /"robot":/      { v = $2; gsub(/[",]/, "", v); robot = v }
        /"tasks_per_s":/ {
            if (fn != "" && med != "") print robot "|" fn "|" med
            fn = ""; med = ""
        }
    ' "$1"
}

# Emit "scenario|class|p99_us|goodput_per_s" per serve row. Same
# alphabetical-key trick: within a row object "scenario" sorts last
# (backoff_us, class, completed, egress_*, expired, goodput_per_s,
# kernel_*, offered_per_s, p50_us, p999_us, p99_us, queue_*, rejected,
# retries, scenario), so it closes the row. The per-stage keys
# ({queue,kernel,egress}_{p50,p99}_us) cannot false-match the
# /"p99_us":/ pattern — their p99_us is preceded by "_", not a quote.
# The top-level "robot"/"schema" keys sort after "rows", so they
# cannot bleed into row state.
extract_serve() {
    awk '
        /"class":/         { v = $2; gsub(/[",]/, "", v); cls = v }
        /"p99_us":/        { v = $2; gsub(/[",]/, "", v); p99 = v }
        /"goodput_per_s":/ { v = $2; gsub(/[",]/, "", v); gput = v }
        /"scenario":/ {
            v = $2; gsub(/[",]/, "", v)
            if (cls != "" && p99 != "") print v "|" cls "|" p99 "|" gput
            cls = ""; p99 = ""; gput = ""
        }
    ' "$1"
}

[ $# -eq 2 ] || usage

if [ "$1" = "--check" ]; then
    f="$2"
    [ -f "$f" ] || { echo "no such file: $f" >&2; exit 1; }
    case "$(schema_of "$f")" in
    hotpath)
        rows="$(extract_hotpath "$f")"
        count="$(printf '%s\n' "$rows" | grep -c '|' || true)"
        if [ "$count" -lt 1 ]; then
            echo "SCHEMA FAIL: no bench rows parsed from $f" >&2
            exit 1
        fi
        # Every kernel and serving row CI depends on must be present.
        for need in \
            "iiwa|fd_ws" \
            "iiwa|fd_quant64_ws" \
            "iiwa|fd_quant_int64" \
            "iiwa|minv_quant_int64" \
            "iiwa|minv_qint_deferred64" \
            "iiwa|fd_qint_srv64" \
            "iiwa|fd_pool64" \
            "iiwa|trace_overhead" \
            "iiwa|dyn_all_fused64" \
            "iiwa|dyn_all_qint64" \
            "iiwa|serve_fd_par64" \
            "iiwa|serve_fd_quant_par64" \
            "iiwa|serve_fd_qint_par64" \
            "iiwa|serve_dyn_all_par64" \
            "iiwa|json_lazy_vs_full" \
            "iiwa|serve_net_jsonl" \
            "mixed|serve_fd_mixed64"; do
            if ! printf '%s\n' "$rows" | grep -q "^${need}|"; then
                echo "SCHEMA FAIL: missing bench row ${need} in $f" >&2
                exit 1
            fi
        done
        if ! printf '%s\n' "$rows" | awk -F'|' '
            $3 + 0 <= 0 { print "SCHEMA FAIL: non-positive median in row " $1 "/" $2; bad = 1 }
            END { exit bad }
        '; then
            exit 1
        fi
        echo "bench schema OK ($count rows in $f)"
        ;;
    serve)
        rows="$(extract_serve "$f")"
        count="$(printf '%s\n' "$rows" | grep -c '|' || true)"
        if [ "$count" -lt 1 ]; then
            echo "SCHEMA FAIL: no serve rows parsed from $f" >&2
            exit 1
        fi
        # The uncontended/overload pair for every QoS class is the
        # tracked envelope, and every run measures the real-engine
        # scenarios (native f64 + true-integer FD routes, plus the FD
        # route over the TCP JSONL wire) and the wire-robustness pair
        # (multi-client bitwise routing, retry/backoff recovery); ramp
        # rows may come and go.
        for need in \
            "uncontended|control" \
            "uncontended|interactive" \
            "uncontended|bulk" \
            "overload|control" \
            "overload|interactive" \
            "overload|bulk" \
            "real-native-fd|bulk" \
            "real-qint-fd|bulk" \
            "real-net-fd|bulk" \
            "serve_net_multi|bulk" \
            "net_retry_recovery|bulk"; do
            if ! printf '%s\n' "$rows" | grep -q "^${need}|"; then
                echo "SCHEMA FAIL: missing serve row ${need} in $f" >&2
                exit 1
            fi
        done
        if ! printf '%s\n' "$rows" | awk -F'|' '
            $3 + 0 < 0 || $4 + 0 < 0 {
                print "SCHEMA FAIL: negative p99/goodput in row " $1 "/" $2; bad = 1
            }
            $4 + 0 > 0 { live = 1 }
            END { if (!live) { print "SCHEMA FAIL: zero goodput in every serve row"; bad = 1 }
                  exit bad }
        '; then
            exit 1
        fi
        # Per-stage latency attribution: every serve dump must carry the
        # queue/kernel/egress breakdown columns (loadgen writes them per
        # scenario; wire-robustness scenarios legitimately hold zeros).
        for key in queue_p50_us queue_p99_us kernel_p50_us kernel_p99_us \
                   egress_p50_us egress_p99_us; do
            if ! grep -q "\"${key}\":" "$f"; then
                echo "SCHEMA FAIL: missing per-stage column \"${key}\" in $f" >&2
                exit 1
            fi
        done
        echo "serve schema OK ($count rows in $f)"
        ;;
    *)
        echo "SCHEMA FAIL: no recognized \"schema\" marker in $f" >&2
        exit 1
        ;;
    esac
    exit 0
fi

old="$1"
new="$2"
[ -f "$old" ] || { echo "no such file: $old" >&2; exit 1; }
[ -f "$new" ] || { echo "no such file: $new" >&2; exit 1; }

if [ "$(schema_of "$old")" = "serve" ] && [ "$(schema_of "$new")" = "serve" ]; then
    printf '%-14s %-12s %12s %12s %9s\n' "scenario" "class" "old p99(us)" "new p99(us)" "delta"
    awk -F'|' '
        NR == FNR { a[$1 "|" $2] = $3; next }
        {
            key = $1 "|" $2
            if (key in a) {
                d = (a[key] > 0) ? ($3 - a[key]) / a[key] * 100 : 0
                printf "%-14s %-12s %12.0f %12.0f %+8.1f%%\n", $1, $2, a[key], $3, d
                delete a[key]
            } else {
                printf "%-14s %-12s %12s %12.0f %9s\n", $1, $2, "-", $3, "(new)"
            }
        }
        END {
            for (k in a) {
                split(k, p, "|")
                printf "%-14s %-12s %12.0f %12s %9s\n", p[1], p[2], a[k], "-", "(gone)"
            }
        }
    ' <(extract_serve "$old") <(extract_serve "$new")
    exit 0
fi

printf '%-10s %-24s %12s %12s %9s\n' "robot" "fn" "old(us)" "new(us)" "delta"
awk -F'|' '
    NR == FNR { a[$1 "|" $2] = $3; next }
    {
        key = $1 "|" $2
        if (key in a) {
            d = (a[key] > 0) ? ($3 - a[key]) / a[key] * 100 : 0
            printf "%-10s %-24s %12.3f %12.3f %+8.1f%%\n", $1, $2, a[key], $3, d
            delete a[key]
        } else {
            printf "%-10s %-24s %12s %12.3f %9s\n", $1, $2, "-", $3, "(new)"
        }
    }
    END {
        for (k in a) {
            split(k, p, "|")
            printf "%-10s %-24s %12.3f %12s %9s\n", p[1], p[2], a[k], "-", "(gone)"
        }
    }
' <(extract_hotpath "$old") <(extract_hotpath "$new")
