#!/usr/bin/env bash
# Compare two BENCH_hotpath.json files row by row, or validate one file
# against the draco.hotpath.v1 schema. Pure bash + awk — no jq/python
# dependency, parses the pretty-printed JSON the bench emits.
#
#   scripts/bench_diff.sh old.json new.json   # per-(robot, fn) median deltas
#   scripts/bench_diff.sh --check file.json   # schema validation (CI runs
#                                             # this on the --quick smoke
#                                             # output)
set -euo pipefail

usage() {
    echo "usage: $0 old.json new.json | $0 --check file.json" >&2
    exit 2
}

# Emit "robot|fn|median_us" per bench row. Relies on the serializer's
# deterministic (BTreeMap, alphabetical) key order within each row
# object: fn, mean_us, median_us, robot, tasks_per_s — so tasks_per_s
# closes a row. The speedups array never carries tasks_per_s, so its
# objects never emit.
extract() {
    awk '
        /"fn":/         { v = $2; gsub(/[",]/, "", v); fn = v }
        /"median_us":/  { v = $2; gsub(/[",]/, "", v); med = v }
        /"robot":/      { v = $2; gsub(/[",]/, "", v); robot = v }
        /"tasks_per_s":/ {
            if (fn != "" && med != "") print robot "|" fn "|" med
            fn = ""; med = ""
        }
    ' "$1"
}

[ $# -eq 2 ] || usage

if [ "$1" = "--check" ]; then
    f="$2"
    [ -f "$f" ] || { echo "no such file: $f" >&2; exit 1; }
    if ! grep -q '"schema": "draco.hotpath.v1"' "$f"; then
        echo "SCHEMA FAIL: missing \"schema\": \"draco.hotpath.v1\" in $f" >&2
        exit 1
    fi
    rows="$(extract "$f")"
    count="$(printf '%s\n' "$rows" | grep -c '|' || true)"
    if [ "$count" -lt 1 ]; then
        echo "SCHEMA FAIL: no bench rows parsed from $f" >&2
        exit 1
    fi
    # Every kernel and serving row CI depends on must be present.
    for need in \
        "iiwa|fd_ws" \
        "iiwa|fd_quant64_ws" \
        "iiwa|fd_quant_int64" \
        "iiwa|minv_quant_int64" \
        "iiwa|minv_qint_deferred64" \
        "iiwa|fd_qint_srv64" \
        "iiwa|fd_pool64" \
        "iiwa|serve_fd_par64" \
        "iiwa|serve_fd_quant_par64" \
        "iiwa|serve_fd_qint_par64" \
        "mixed|serve_fd_mixed64"; do
        if ! printf '%s\n' "$rows" | grep -q "^${need}|"; then
            echo "SCHEMA FAIL: missing bench row ${need} in $f" >&2
            exit 1
        fi
    done
    if ! printf '%s\n' "$rows" | awk -F'|' '
        $3 + 0 <= 0 { print "SCHEMA FAIL: non-positive median in row " $1 "/" $2; bad = 1 }
        END { exit bad }
    '; then
        exit 1
    fi
    echo "bench schema OK ($count rows in $f)"
    exit 0
fi

old="$1"
new="$2"
[ -f "$old" ] || { echo "no such file: $old" >&2; exit 1; }
[ -f "$new" ] || { echo "no such file: $new" >&2; exit 1; }

printf '%-10s %-24s %12s %12s %9s\n' "robot" "fn" "old(us)" "new(us)" "delta"
awk -F'|' '
    NR == FNR { a[$1 "|" $2] = $3; next }
    {
        key = $1 "|" $2
        if (key in a) {
            d = (a[key] > 0) ? ($3 - a[key]) / a[key] * 100 : 0
            printf "%-10s %-24s %12.3f %12.3f %+8.1f%%\n", $1, $2, a[key], $3, d
            delete a[key]
        } else {
            printf "%-10s %-24s %12s %12.3f %9s\n", $1, $2, "-", $3, "(new)"
        }
    }
    END {
        for (k in a) {
            split(k, p, "|")
            printf "%-10s %-24s %12.3f %12s %9s\n", p[1], p[2], a[k], "-", "(gone)"
        }
    }
' <(extract "$old") <(extract "$new")
