"""L2 model checks: batched JAX RBD vs the single-sample oracle, algebraic
invariants (M⁻¹M = I, FD∘ID = identity), shapes, and the AOT text path."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model, robots
from compile.kernels import ref

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

ROBOTS = {name: robots.load(name) for name in ["iiwa", "hyq", "baxter"]}


def rand_state(rob, rng, b):
    q = rng.uniform(-1.2, 1.2, (b, rob.n)).astype(np.float32)
    qd = rng.uniform(-1, 1, (b, rob.n)).astype(np.float32)
    qdd = rng.uniform(-1, 1, (b, rob.n)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(qd), jnp.asarray(qdd)


@pytest.mark.parametrize("name", list(ROBOTS))
def test_batched_rnea_matches_ref(name):
    rob = ROBOTS[name]
    rng = np.random.default_rng(7)
    q, qd, qdd = rand_state(rob, rng, 5)
    got = model.batched_rnea(rob, q, qd, qdd)
    for i in range(5):
        want = ref.rnea(rob, q[i], qd[i], qdd[i])
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(want), rtol=2e-3, atol=2e-3
        )


@pytest.mark.parametrize("name", list(ROBOTS))
def test_minv_times_m_is_identity(name):
    rob = ROBOTS[name]
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.uniform(-1, 1, rob.n).astype(np.float32))
    mi = ref.minv_dd(rob, q)
    m = ref.crba(rob, q)
    err = float(jnp.max(jnp.abs(mi @ m - jnp.eye(rob.n))))
    assert err < 5e-3, f"{name}: |M⁻¹M − I| = {err}"


@given(seed=st.integers(0, 2**31 - 1))
def test_fd_inverts_id_iiwa(seed):
    rob = ROBOTS["iiwa"]
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.uniform(-1, 1, rob.n).astype(np.float32))
    qd = jnp.asarray(rng.uniform(-0.5, 0.5, rob.n).astype(np.float32))
    qdd_in = jnp.asarray(rng.uniform(-1, 1, rob.n).astype(np.float32))
    tau = ref.rnea(rob, q, qd, qdd_in)
    qdd_out = ref.fd(rob, q, qd, tau)
    np.testing.assert_allclose(
        np.asarray(qdd_out), np.asarray(qdd_in), rtol=2e-2, atol=2e-2
    )


def test_batched_fd_shapes_and_consistency():
    rob = ROBOTS["iiwa"]
    rng = np.random.default_rng(9)
    q, qd, _ = rand_state(rob, rng, 4)
    tau = jnp.asarray(rng.uniform(-5, 5, (4, rob.n)).astype(np.float32))
    out = model.batched_fd(rob, q, qd, tau)
    assert out.shape == (4, rob.n)
    want = ref.fd(rob, q[0], qd[0], tau[0])
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want), rtol=5e-3, atol=5e-3)


def test_batched_minv_shape_and_symmetry():
    rob = ROBOTS["iiwa"]
    rng = np.random.default_rng(10)
    q, _, _ = rand_state(rob, rng, 3)
    mi = model.batched_minv(rob, q)
    assert mi.shape == (3, rob.n, rob.n)
    asym = float(jnp.max(jnp.abs(mi - jnp.swapaxes(mi, 1, 2))))
    assert asym < 5e-3, f"M⁻¹ should be symmetric, asym={asym}"


def test_quantized_model_tracks_float_at_high_precision():
    rob = ROBOTS["iiwa"]
    rng = np.random.default_rng(11)
    q, qd, qdd = rand_state(rob, rng, 4)
    exact = model.batched_rnea(rob, q, qd, qdd)
    quant = model.batched_rnea(rob, q, qd, qdd, fmt=(14, 16))
    np.testing.assert_allclose(np.asarray(exact), np.asarray(quant), rtol=1e-2, atol=1e-2)


def test_quantized_model_degrades_at_coarse_precision():
    rob = ROBOTS["iiwa"]
    rng = np.random.default_rng(12)
    q, qd, qdd = rand_state(rob, rng, 8)
    exact = model.batched_rnea(rob, q, qd, qdd)
    fine = model.batched_rnea(rob, q, qd, qdd, fmt=(12, 14))
    coarse = model.batched_rnea(rob, q, qd, qdd, fmt=(12, 6))
    e_fine = float(jnp.mean(jnp.abs(exact - fine)))
    e_coarse = float(jnp.mean(jnp.abs(exact - coarse)))
    assert e_coarse > e_fine


def test_hlo_text_has_no_elided_constants():
    # Regression guard for the print_large_constants pitfall: an elided
    # `constant({...})` parses back as ZEROS in xla_extension 0.5.1.
    from compile.aot import lower_fn

    text = lower_fn(ROBOTS["iiwa"], "rnea", 4)
    assert "constant({...})" not in text
    assert "ENTRY" in text


def test_aot_covers_requested_functions(tmp_path):
    from compile.aot import lower_fn

    for fn in ["rnea", "fd", "minv"]:
        text = lower_fn(ROBOTS["iiwa"], fn, 2)
        assert len(text) > 10_000, f"{fn}: implausibly small HLO"
