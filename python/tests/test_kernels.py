"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps batch sizes / seeds / Q-formats; every kernel output is
compared against the reference composition with `assert_allclose`.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import robots
from compile.kernels import ref
from compile.kernels.spatial import mat6_apply, rnea_step, xmotion_apply

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

ROB = robots.load("iiwa")


def rand_rot(rng):
    axis = rng.normal(size=3)
    axis /= np.linalg.norm(axis)
    return np.asarray(ref.rot_axis(jnp.asarray(axis), rng.uniform(-3, 3)))


@given(b=st.integers(1, 70), seed=st.integers(0, 2**31 - 1))
def test_xmotion_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    e = np.stack([rand_rot(rng) for _ in range(b)]).astype(np.float32)
    r = rng.uniform(-1, 1, (b, 3)).astype(np.float32)
    v = rng.uniform(-2, 2, (b, 6)).astype(np.float32)
    got = xmotion_apply(jnp.asarray(e), jnp.asarray(r), jnp.asarray(v))
    want = np.stack(
        [np.asarray(ref.x_apply(e[i], r[i], v[i])) for i in range(b)]
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@given(b=st.integers(1, 70), seed=st.integers(0, 2**31 - 1))
def test_mat6_apply_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    m = rng.uniform(-2, 2, (6, 6)).astype(np.float32)
    v = rng.uniform(-2, 2, (b, 6)).astype(np.float32)
    got = mat6_apply(jnp.asarray(m), jnp.asarray(v))
    want = v @ m.T
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


@given(
    b=st.integers(1, 40),
    joint=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_rnea_step_matches_ref_composition(b, joint, seed):
    rng = np.random.default_rng(seed)
    qv = rng.uniform(-1.5, 1.5, b)
    qd = rng.uniform(-1, 1, b).astype(np.float32)
    qdd = rng.uniform(-1, 1, b).astype(np.float32)
    vp = rng.uniform(-1, 1, (b, 6)).astype(np.float32)
    ap = rng.uniform(-1, 1, (b, 6)).astype(np.float32)
    es, rs = [], []
    for i in range(b):
        e, r = ref.joint_xform(ROB, joint, qv[i])
        es.append(np.asarray(e))
        rs.append(np.asarray(r))
    e = np.stack(es).astype(np.float32)
    r = np.stack(rs).astype(np.float32)
    s = ref.motion_subspace(ROB, joint).astype(jnp.float32)
    inert = jnp.asarray(ROB.inertia[joint], dtype=jnp.float32)

    v, a, f = rnea_step(
        jnp.asarray(e), jnp.asarray(r), inert, s,
        jnp.asarray(vp), jnp.asarray(ap), jnp.asarray(qd), jnp.asarray(qdd),
    )
    for i in range(b):
        vi = ref.x_apply(e[i], r[i], vp[i]) + s * qd[i]
        ai = ref.x_apply(e[i], r[i], ap[i]) + s * qdd[i] + ref.crm(vi, s * qd[i])
        fi = inert @ ai + ref.crf(vi, inert @ vi)
        np.testing.assert_allclose(np.asarray(v[i]), np.asarray(vi), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(a[i]), np.asarray(ai), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(f[i]), np.asarray(fi), rtol=2e-4, atol=2e-3)


@given(
    int_bits=st.integers(6, 14),
    frac_bits=st.integers(4, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_in_kernel_quantization_matches_ref_quantize(int_bits, frac_bits, seed):
    rng = np.random.default_rng(seed)
    m = rng.uniform(-2, 2, (6, 6)).astype(np.float32)
    v = rng.uniform(-2, 2, (8, 6)).astype(np.float32)
    got = mat6_apply(jnp.asarray(m), jnp.asarray(v), fmt=(int_bits, frac_bits))
    want = ref.quantize(jnp.asarray(v @ m.T), int_bits, frac_bits)
    # The in-kernel accumulation may differ from the outside product by
    # one f32 ulp, which can flip a rounding bin: allow one Q step.
    step = 2.0 ** (-frac_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=step * 1.001)


def test_quantize_error_bounded_by_eps():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-100, 100, 1000).astype(np.float32))
    for frac in [6, 10, 14]:
        q = ref.quantize(x, 12, frac)
        eps = 2.0 ** (-frac - 1)
        assert float(jnp.max(jnp.abs(q - x))) <= eps * 1.001


def test_quantize_saturates():
    q = ref.quantize(jnp.asarray([1e9, -1e9]), 8, 8)
    assert float(q[0]) <= 2.0**7
    assert float(q[1]) >= -(2.0**7) - 2.0**-8


@pytest.mark.parametrize("block", [8, 32])
def test_block_size_irrelevant(block):
    rng = np.random.default_rng(3)
    m = rng.uniform(-1, 1, (6, 6)).astype(np.float32)
    v = rng.uniform(-1, 1, (50, 6)).astype(np.float32)
    a = mat6_apply(jnp.asarray(m), jnp.asarray(v), block=block)
    b = mat6_apply(jnp.asarray(m), jnp.asarray(v), block=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)
