"""Robot descriptions for the compile path.

Loads the JSON robot files exported by ``draco export-robots`` (the Rust
side is the source of truth; `data/robots/*.json` is the shared format)
into flat numpy arrays convenient for JAX tracing.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

import numpy as np

_DATA_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "data", "robots")

REVOLUTE = 0
PRISMATIC = 1


@dataclass
class RobotArrays:
    name: str
    parent: np.ndarray  # (N,) int, -1 for base children
    jtype: np.ndarray  # (N,) int
    axis: np.ndarray  # (N,3)
    e_tree: np.ndarray  # (N,3,3) fixed tree rotation (coordinate transform)
    r_tree: np.ndarray  # (N,3)
    inertia: np.ndarray  # (N,6,6) spatial inertia at link frame origin
    gravity: np.ndarray  # (3,)

    @property
    def n(self) -> int:
        return len(self.parent)

    def subtree(self, i: int) -> list[int]:
        mark = [False] * self.n
        mark[i] = True
        for j in range(i + 1, self.n):
            p = int(self.parent[j])
            if p >= 0 and mark[p]:
                mark[j] = True
        return [j for j in range(self.n) if mark[j]]


def _skew(v):
    return np.array(
        [[0.0, -v[2], v[1]], [v[2], 0.0, -v[0]], [-v[1], v[0], 0.0]]
    )


def _mat6(mass: float, com: np.ndarray, i_o: np.ndarray) -> np.ndarray:
    m = np.zeros((6, 6))
    mcx = mass * _skew(com)
    m[:3, :3] = i_o
    m[:3, 3:] = mcx
    m[3:, :3] = -mcx
    m[3:, 3:] = mass * np.eye(3)
    return m


def load(name: str, data_dir: str | None = None) -> RobotArrays:
    path = os.path.join(data_dir or _DATA_DIR, f"{name}.json")
    with open(path) as f:
        doc = json.load(f)
    links = doc["links"]
    n = len(links)
    parent = np.zeros(n, dtype=np.int64)
    jtype = np.zeros(n, dtype=np.int64)
    axis = np.zeros((n, 3))
    e_tree = np.zeros((n, 3, 3))
    r_tree = np.zeros((n, 3))
    inertia = np.zeros((n, 6, 6))
    for i, l in enumerate(links):
        parent[i] = l["parent"]
        jtype[i] = PRISMATIC if l["joint_type"] == "prismatic" else REVOLUTE
        axis[i] = np.asarray(l["axis"], dtype=float)
        axis[i] /= np.linalg.norm(axis[i])
        e_tree[i] = np.asarray(l["tree_rot"], dtype=float)
        r_tree[i] = np.asarray(l["tree_xyz"], dtype=float)
        inertia[i] = _mat6(
            float(l["mass"]),
            np.asarray(l["com"], dtype=float),
            np.asarray(l["inertia_o"], dtype=float),
        )
    return RobotArrays(
        name=doc["name"],
        parent=parent,
        jtype=jtype,
        axis=axis,
        e_tree=e_tree,
        r_tree=r_tree,
        inertia=inertia,
        gravity=np.asarray(doc["gravity"], dtype=float),
    )


def available(data_dir: str | None = None) -> list[str]:
    d = data_dir or _DATA_DIR
    return sorted(
        f[: -len(".json")] for f in os.listdir(d) if f.endswith(".json")
    )
