"""Layer-2 JAX model: batched RBD functions over a robot description,
built on the Layer-1 Pallas kernels, lowered once by ``aot.py``.

The batch dimension plays the role of the RTP task stream: each joint's
forward/backward computation is one pipeline stage (a fused Pallas unit),
and the (B, N) operands stream through the stages exactly as tasks stream
through the accelerator's Uf/Ub units.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref
from .kernels.spatial import mat6_apply, rnea_step, xmotion_apply
from .robots import PRISMATIC, RobotArrays


def _batched_joint_xform(rob: RobotArrays, i: int, qi):
    """(E, r) for joint i across a batch: (B,3,3), (B,3)."""
    axis = jnp.asarray(rob.axis[i])
    e_tree = jnp.asarray(rob.e_tree[i])
    r_tree = jnp.asarray(rob.r_tree[i])
    b = qi.shape[0]
    if int(rob.jtype[i]) == PRISMATIC:
        ej = jnp.broadcast_to(jnp.eye(3), (b, 3, 3))
        rj = axis[None, :] * qi[:, None]
    else:
        k = ref.skew(axis)
        k2 = k @ k
        ej = (
            jnp.eye(3)[None, :, :]
            - jnp.sin(qi)[:, None, None] * k[None, :, :]
            + (1.0 - jnp.cos(qi))[:, None, None] * k2[None, :, :]
        )
        rj = jnp.zeros((b, 3))
    e = jnp.einsum("bij,jk->bik", ej, e_tree)
    r = r_tree[None, :] + jnp.einsum("ji,bj->bi", e_tree, rj)
    return e, r


def batched_rnea(rob: RobotArrays, q, qd, qdd, fmt=None):
    """τ = ID(q, q̇, q̈) for a batch: all inputs (B, N) → (B, N).

    Forward pass runs the fused Pallas unit per joint; the backward pass
    uses the motion-transform kernel on force vectors (Xᵀ f implemented
    via the transposed-transform identity).
    """
    n = rob.n
    b = q.shape[0]
    a0 = jnp.broadcast_to(
        jnp.concatenate([jnp.zeros(3), -jnp.asarray(rob.gravity)]), (b, 6)
    ).astype(q.dtype)
    zeros6 = jnp.zeros((b, 6), dtype=q.dtype)

    es, rs = [], []
    v = [None] * n
    a = [None] * n
    f = [None] * n
    for i in range(n):
        e, r = _batched_joint_xform(rob, i, q[:, i])
        es.append(e)
        rs.append(r)
        s = ref.motion_subspace(rob, i)
        p = int(rob.parent[i])
        vp = v[p] if p >= 0 else zeros6
        ap = a[p] if p >= 0 else a0
        vi, ai, fi = rnea_step(
            e, r, jnp.asarray(rob.inertia[i], dtype=q.dtype), s.astype(q.dtype),
            vp, ap, q.dtype.type(0) + qd[:, i], qdd[:, i], fmt=fmt,
        )
        v[i], a[i], f[i] = vi, ai, fi

    tau_cols = [None] * n
    for i in reversed(range(n)):
        s = ref.motion_subspace(rob, i).astype(q.dtype)
        tau_cols[i] = f[i] @ s
        p = int(rob.parent[i])
        if p >= 0:
            # Force transform to parent: lin_p = Eᵀ lin, ang_p = Eᵀ ang + r×lin_p.
            # Equivalent to the motion transform with (Eᵀ, −E r): reuse the
            # motion kernel on the swapped halves.
            et = jnp.swapaxes(es[i], 1, 2)
            r_neg = -jnp.einsum("bij,bj->bi", es[i], rs[i])
            swapped = jnp.concatenate([f[i][:, 3:], f[i][:, :3]], axis=1)
            out = xmotion_apply(et, r_neg, swapped, fmt=fmt)
            fp = jnp.concatenate([out[:, 3:], out[:, :3]], axis=1)
            f[p] = f[p] + fp
    return jnp.stack(tau_cols, axis=1)


def batched_bias(rob: RobotArrays, q, qd, fmt=None):
    """C(q, q̇) = RNEA(q, q̇, 0)."""
    return batched_rnea(rob, q, qd, jnp.zeros_like(q), fmt=fmt)


def batched_minv(rob: RobotArrays, q, fmt=None):
    """M⁻¹(q) per batch element: (B,N) → (B,N,N), division-deferring
    form (one vectorized reciprocal stage — see ref.minv_dd)."""
    import jax

    out = jax.vmap(lambda qi: ref.minv_dd(rob, qi))(q)
    if fmt is not None:
        out = ref.quantize(out, *fmt)
    return out


def batched_fd(rob: RobotArrays, q, qd, tau, fmt=None):
    """q̈ = M⁻¹ · (τ − C): the paper's Eq. 2 composition, batched. The
    final contraction is the inter-module 'glue' matvec of the FD
    pipeline."""
    bias = batched_bias(rob, q, qd, fmt=fmt)
    mi = batched_minv(rob, q, fmt=fmt)
    rhs = tau - bias
    out = jnp.einsum("bij,bj->bi", mi, rhs)
    if fmt is not None:
        out = ref.quantize(out, *fmt)
    return out


def batched_inertia_apply(rob: RobotArrays, i: int, v, fmt=None):
    """Expose the constant-matrix MAC kernel for tests/benches."""
    return mat6_apply(jnp.asarray(rob.inertia[i], dtype=v.dtype), v, fmt=fmt)
