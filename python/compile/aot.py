"""AOT compile path: lower the L2 batched RBD functions to HLO **text**
artifacts the Rust runtime loads via `HloModuleProto::from_text_file`.

HLO text (NOT `.serialize()`): jax ≥ 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts \
        [--robots iiwa,hyq] [--fns rnea,fd,minv] [--batches 16,64]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, robots


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is REQUIRED: the default printer elides
    # literals with >15 elements to `constant({...})`, which the XLA
    # 0.5.1 text parser silently reads back as ZEROS — the robot's
    # inertia/transform constants would all vanish.
    return comp.as_hlo_text(print_large_constants=True)


def lower_fn(rob: robots.RobotArrays, fn: str, batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, rob.n), jnp.float32)
    if fn == "rnea":
        f = lambda q, qd, qdd: (model.batched_rnea(rob, q, qd, qdd),)
        lowered = jax.jit(f).lower(spec, spec, spec)
    elif fn == "fd":
        f = lambda q, qd, tau: (model.batched_fd(rob, q, qd, tau),)
        lowered = jax.jit(f).lower(spec, spec, spec)
    elif fn == "minv":
        f = lambda q: (model.batched_minv(rob, q),)
        lowered = jax.jit(f).lower(spec)
    else:
        raise ValueError(f"unknown fn '{fn}'")
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--robots", default="iiwa,hyq")
    ap.add_argument("--fns", default="rnea,fd,minv")
    ap.add_argument("--batches", default="16,64")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    robot_names = args.robots.split(",")
    fns = args.fns.split(",")
    batches = [int(b) for b in args.batches.split(",")]

    for name in robot_names:
        rob = robots.load(name)
        for fn in fns:
            for b in batches:
                out = os.path.join(args.out_dir, f"{name}_{fn}_b{b}.hlo.txt")
                text = lower_fn(rob, fn, b)
                with open(out, "w") as fh:
                    fh.write(text)
                print(f"wrote {out} ({len(text) / 1e3:.0f} kB)")


if __name__ == "__main__":
    main()
