"""Pure-jnp single-sample reference implementations — the correctness
oracle for the Pallas kernels and the batched L2 model.

Conventions follow the Rust library exactly (Featherstone, angular part
first):

* ``rot_axis(axis, q)`` is the *coordinate transform* E (transpose of the
  vector-rotation matrix).
* Motion transform: ``ang' = E·ang``, ``lin' = E·(lin − r×ang)``.
* Force transform (to parent): ``lin_p = Eᵀ·lin``, ``ang_p = Eᵀ·ang + r×lin_p``.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..robots import PRISMATIC, RobotArrays


def skew(v):
    return jnp.array(
        [
            [0.0, -v[2], v[1]],
            [v[2], 0.0, -v[0]],
            [-v[1], v[0], 0.0],
        ]
    )


def rot_axis(axis, q):
    """Coordinate-transform rotation (E-style): I − sin·K + (1−cos)·K²."""
    k = skew(axis)
    return jnp.eye(3) - jnp.sin(q) * k + (1.0 - jnp.cos(q)) * (k @ k)


def joint_xform(rob: RobotArrays, i: int, qi):
    """X_up[i] = XJ(q_i) ∘ X_tree[i] → (E, r)."""
    if int(rob.jtype[i]) == PRISMATIC:
        ej = jnp.eye(3)
        rj = jnp.asarray(rob.axis[i]) * qi
    else:
        ej = rot_axis(jnp.asarray(rob.axis[i]), qi)
        rj = jnp.zeros(3)
    e = ej @ rob.e_tree[i]
    r = jnp.asarray(rob.r_tree[i]) + rob.e_tree[i].T @ rj
    return e, r


def motion_subspace(rob: RobotArrays, i: int):
    if int(rob.jtype[i]) == PRISMATIC:
        return jnp.concatenate([jnp.zeros(3), jnp.asarray(rob.axis[i])])
    return jnp.concatenate([jnp.asarray(rob.axis[i]), jnp.zeros(3)])


def x_apply(e, r, v):
    ang = e @ v[:3]
    lin = e @ (v[3:] - jnp.cross(r, v[:3]))
    return jnp.concatenate([ang, lin])


def inv_apply_force(e, r, f):
    lin = e.T @ f[3:]
    ang = e.T @ f[:3] + jnp.cross(r, lin)
    return jnp.concatenate([ang, lin])


def crm(v, m):
    ang = jnp.cross(v[:3], m[:3])
    lin = jnp.cross(v[:3], m[3:]) + jnp.cross(v[3:], m[:3])
    return jnp.concatenate([ang, lin])


def crf(v, f):
    ang = jnp.cross(v[:3], f[:3]) + jnp.cross(v[3:], f[3:])
    lin = jnp.cross(v[:3], f[3:])
    return jnp.concatenate([ang, lin])


def xform_mat6(e, r):
    """Dense 6×6 motion transform [[E,0],[−E·r̃,E]]."""
    xm = jnp.zeros((6, 6))
    xm = xm.at[:3, :3].set(e).at[3:, 3:].set(e)
    return xm.at[3:, :3].set(-(e @ skew(r)))


def rnea(rob: RobotArrays, q, qd, qdd):
    """Inverse dynamics τ = ID(q, q̇, q̈), single sample."""
    n = rob.n
    a0 = jnp.concatenate([jnp.zeros(3), -jnp.asarray(rob.gravity)])
    v = [None] * n
    a = [None] * n
    f = [None] * n
    xs = []
    for i in range(n):
        e, r = joint_xform(rob, i, q[i])
        xs.append((e, r))
        s = motion_subspace(rob, i)
        p = int(rob.parent[i])
        vp = v[p] if p >= 0 else jnp.zeros(6)
        ap = a[p] if p >= 0 else a0
        vi = x_apply(e, r, vp) + s * qd[i]
        ai = x_apply(e, r, ap) + s * qdd[i] + crm(vi, s * qd[i])
        ii = jnp.asarray(rob.inertia[i])
        fi = ii @ ai + crf(vi, ii @ vi)
        v[i], a[i], f[i] = vi, ai, fi
    tau = [None] * n
    for i in reversed(range(n)):
        s = motion_subspace(rob, i)
        tau[i] = s @ f[i]
        p = int(rob.parent[i])
        if p >= 0:
            e, r = xs[i]
            f[p] = f[p] + inv_apply_force(e, r, f[i])
    return jnp.stack(tau)


def crba(rob: RobotArrays, q):
    """Mass matrix via RNEA columns (zero velocity, unit accelerations)."""
    n = rob.n
    zero = jnp.zeros(n)
    t0 = rnea(rob, q, zero, zero)
    cols = []
    for j in range(n):
        ej = jnp.zeros(n).at[j].set(1.0)
        cols.append(rnea(rob, q, zero, ej) - t0)
    return jnp.stack(cols, axis=1)


def minv_dd(rob: RobotArrays, q):
    """Analytical M⁻¹ in the division-deferring form (paper Alg. 2): the
    backward sweep forms scaled numerators only; ALL reciprocals run as
    one vectorized stage (the shared pipelined divider); the forward
    sweep consumes them. Columns are vectorized as (6, N) blocks.
    """
    n = rob.n
    ia = [jnp.asarray(rob.inertia[i]) for i in range(n)]
    xs = [joint_xform(rob, i, q[i]) for i in range(n)]
    ss = [motion_subspace(rob, i) for i in range(n)]

    u = [None] * n
    d = [None] * n
    f = [jnp.zeros((6, n)) for _ in range(n)]
    raw_row = [jnp.zeros(n) for _ in range(n)]

    for i in reversed(range(n)):
        s = ss[i]
        ui = ia[i] @ s
        di = s @ ui
        u[i], d[i] = ui, di
        raw_row[i] = raw_row[i].at[i].add(1.0) - s @ f[i]
        p = int(rob.parent[i])
        if p >= 0:
            e, r = xs[i]
            # N_i = D·IA − U Uᵀ, propagated with the divider output 1/D.
            ni = di * ia[i] - jnp.outer(ui, ui)
            xm = xform_mat6(e, r)
            ia[p] = ia[p] + (xm.T @ ni @ xm) * (1.0 / di)
            # G_i = D·F + U·raw_row; force-transform each column.
            gi = di * f[i] + jnp.outer(ui, raw_row[i])
            lin = e.T @ gi[3:]  # (3, n)
            ang = e.T @ gi[:3] + jnp.cross(jnp.broadcast_to(r, (n, 3)), lin.T).T
            f[p] = f[p] + jnp.concatenate([ang, lin], axis=0) * (1.0 / di)

    # Shared-divider stage: one vectorized reciprocal over all joints.
    dinv = 1.0 / jnp.stack(d)

    minv = jnp.stack([raw_row[i] * dinv[i] for i in range(n)])
    a = [jnp.zeros((6, n)) for _ in range(n)]
    for i in range(n):
        s = ss[i]
        p = int(rob.parent[i])
        if p < 0:
            a[i] = jnp.outer(s, minv[i])
        else:
            e, r = xs[i]
            # Motion-transform each column of a[p].
            lin_in = a[p][3:] - jnp.cross(jnp.broadcast_to(r, (n, 3)), a[p][:3].T).T
            xa = jnp.concatenate([e @ a[p][:3], e @ lin_in], axis=0)
            corr = dinv[i] * (u[i] @ xa)
            minv = minv.at[i].add(-corr)
            a[i] = xa + jnp.outer(s, minv[i])
    return minv


def fd(rob: RobotArrays, q, qd, tau):
    """Forward dynamics q̈ = M⁻¹·(τ − C) (paper Eq. 2)."""
    bias = rnea(rob, q, qd, jnp.zeros(rob.n))
    return minv_dd(rob, q) @ (tau - bias)


def quantize(x, int_bits: int, frac_bits: int):
    """Round-to-nearest + saturate Q-format emulation (matches the Rust
    ``QFormat::q``)."""
    step = 2.0 ** (-frac_bits)
    max_val = 2.0 ** (int_bits - 1) - step
    v = jnp.round(x / step) * step
    return jnp.clip(v, -max_val - step, max_val)
