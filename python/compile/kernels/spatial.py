"""Layer-1 Pallas kernels: the RBD MAC hot-spot as batched, VMEM-tiled
kernels with in-kernel Q-format quantization emulation.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper maps MACs
onto FPGA DSP slices and streams tasks through per-joint RTP stages; on
TPU the batch dimension provides the streaming (BlockSpec tiles of the
batch live in VMEM) and reduced-precision MACs map to quantized values
flowing through the MXU. ``interpret=True`` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so kernels lower to plain HLO
(numerics identical; real-TPU perf is estimated in DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32


def _quant(x, fmt):
    """In-kernel Q-format rounding: round-to-nearest + saturate."""
    if fmt is None:
        return x
    int_bits, frac_bits = fmt
    step = 2.0 ** (-frac_bits)
    max_val = 2.0 ** (int_bits - 1) - step
    return jnp.clip(jnp.round(x / step) * step, -max_val - step, max_val)


def _cross(a, b):
    """Batched 3-D cross product on (B,3) tiles (index form keeps the
    kernel free of gather ops)."""
    c0 = a[:, 1] * b[:, 2] - a[:, 2] * b[:, 1]
    c1 = a[:, 2] * b[:, 0] - a[:, 0] * b[:, 2]
    c2 = a[:, 0] * b[:, 1] - a[:, 1] * b[:, 0]
    return jnp.stack([c0, c1, c2], axis=1)


def _pad_batch(x, block):
    b = x.shape[0]
    pad = (-b) % block
    if pad == 0:
        return x, b
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths), b


# --------------------------------------------------------------------
# Kernel 1: batched spatial motion transform  o = X(e, r) · v
# --------------------------------------------------------------------


def _xmotion_kernel(e_ref, r_ref, v_ref, o_ref, *, fmt):
    e = e_ref[...]  # (BK, 3, 3)
    r = r_ref[...]  # (BK, 3)
    v = v_ref[...]  # (BK, 6)
    ang_in = v[:, :3]
    lin_in = v[:, 3:]
    rx = _cross(r, ang_in)
    ang = jnp.einsum("bij,bj->bi", e, ang_in)
    lin = jnp.einsum("bij,bj->bi", e, lin_in - rx)
    o_ref[...] = _quant(jnp.concatenate([ang, lin], axis=1), fmt)


def xmotion_apply(e, r, v, fmt=None, block=BLOCK):
    """Batched Plücker motion transform: (B,3,3),(B,3),(B,6) → (B,6)."""
    (e, b0) = _pad_batch(e, block)
    (r, _) = _pad_batch(r, block)
    (v, _) = _pad_batch(v, block)
    b = e.shape[0]
    grid = (b // block,)
    out = pl.pallas_call(
        functools.partial(_xmotion_kernel, fmt=fmt),
        out_shape=jax.ShapeDtypeStruct((b, 6), v.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 3, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, 3), lambda i: (i, 0)),
            pl.BlockSpec((block, 6), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 6), lambda i: (i, 0)),
        interpret=True,
    )(e, r, v)
    return out[:b0]


# --------------------------------------------------------------------
# Kernel 2: batched constant-matrix MAC  o = v · Mᵀ   (the DSP array)
# --------------------------------------------------------------------


def _mat6_kernel(m_ref, v_ref, o_ref, *, fmt):
    m = m_ref[...]  # (6, 6)
    v = v_ref[...]  # (BK, 6)
    o_ref[...] = _quant(v @ m.T, fmt)


def mat6_apply(m, v, fmt=None, block=BLOCK):
    """Batched spatial-inertia application: (6,6) const × (B,6) → (B,6)."""
    (v, b0) = _pad_batch(v, block)
    b = v.shape[0]
    out = pl.pallas_call(
        functools.partial(_mat6_kernel, fmt=fmt),
        out_shape=jax.ShapeDtypeStruct((b, 6), v.dtype),
        grid=(b // block,),
        in_specs=[
            pl.BlockSpec((6, 6), lambda i: (0, 0)),
            pl.BlockSpec((block, 6), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 6), lambda i: (i, 0)),
        interpret=True,
    )(m, v)
    return out[:b0]


# --------------------------------------------------------------------
# Kernel 3: fused RNEA forward step (one Uf pipeline unit, batched)
# --------------------------------------------------------------------


def _rnea_step_kernel(
    e_ref, r_ref, inert_ref, s_ref, vp_ref, ap_ref, qd_ref, qdd_ref,
    v_ref, a_ref, f_ref, *, fmt,
):
    e = e_ref[...]
    r = r_ref[...]
    inert = inert_ref[...]  # (6,6)
    s = s_ref[...]  # (1,6)
    vp = vp_ref[...]
    ap = ap_ref[...]
    qd = qd_ref[...]  # (BK,1)
    qdd = qdd_ref[...]

    def xapply(vec):
        ang_in = vec[:, :3]
        lin_in = vec[:, 3:]
        ang = jnp.einsum("bij,bj->bi", e, ang_in)
        lin = jnp.einsum("bij,bj->bi", e, lin_in - _cross(r, ang_in))
        return jnp.concatenate([ang, lin], axis=1)

    vj = s * qd  # (BK,6)
    vi = _quant(xapply(vp) + vj, fmt)
    # crm(vi, vj)
    ang = _cross(vi[:, :3], vj[:, :3])
    lin = _cross(vi[:, :3], vj[:, 3:]) + _cross(vi[:, 3:], vj[:, :3])
    ai = _quant(xapply(ap) + s * qdd + jnp.concatenate([ang, lin], axis=1), fmt)
    ia = ai @ inert.T
    iv = vi @ inert.T
    # crf(vi, iv)
    angf = _cross(vi[:, :3], iv[:, :3]) + _cross(vi[:, 3:], iv[:, 3:])
    linf = _cross(vi[:, :3], iv[:, 3:])
    fi = _quant(ia + jnp.concatenate([angf, linf], axis=1), fmt)
    v_ref[...] = vi
    a_ref[...] = ai
    f_ref[...] = fi


def rnea_step(e, r, inert, s, vp, ap, qd, qdd, fmt=None, block=BLOCK):
    """Fused forward-pass unit: returns (v_i, a_i, f_i), each (B,6).

    ``e``/``r`` are per-task joint transforms (B,3,3)/(B,3); ``inert``
    (6,6) and ``s`` (6,) are per-joint constants; ``qd``/``qdd`` are (B,).
    """
    (e, b0) = _pad_batch(e, block)
    (r, _) = _pad_batch(r, block)
    (vp, _) = _pad_batch(vp, block)
    (ap, _) = _pad_batch(ap, block)
    (qd2, _) = _pad_batch(qd[:, None], block)
    (qdd2, _) = _pad_batch(qdd[:, None], block)
    b = e.shape[0]
    shp = jax.ShapeDtypeStruct((b, 6), vp.dtype)
    outs = pl.pallas_call(
        functools.partial(_rnea_step_kernel, fmt=fmt),
        out_shape=(shp, shp, shp),
        grid=(b // block,),
        in_specs=[
            pl.BlockSpec((block, 3, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, 3), lambda i: (i, 0)),
            pl.BlockSpec((6, 6), lambda i: (0, 0)),
            pl.BlockSpec((1, 6), lambda i: (0, 0)),
            pl.BlockSpec((block, 6), lambda i: (i, 0)),
            pl.BlockSpec((block, 6), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block, 6), lambda i: (i, 0)),
            pl.BlockSpec((block, 6), lambda i: (i, 0)),
            pl.BlockSpec((block, 6), lambda i: (i, 0)),
        ),
        interpret=True,
    )(e, r, inert, s[None, :], vp, ap, qd2, qdd2)
    return outs[0][:b0], outs[1][:b0], outs[2][:b0]
