//! The paper's §III workflow: run the precision-aware bit-width search
//! for the iiwa under each controller template and report the chosen
//! formats — reproducing the §V-A finding that PID needs the most
//! fractional bits and MPC tolerates the fewest.
//!
//! Run: `cargo run --release --example quantization_search`

use draco::model::builtin_robot;
use draco::quant::search::{search, Requirements};
use draco::sim::icms::ControllerKind;
use draco::util::bench::Table;

fn main() {
    let robot = builtin_robot("iiwa").unwrap();
    // ±0.5 mm trajectory-error tolerance (§V-A).
    let req = Requirements { traj_tol: 5e-4, ..Default::default() };

    let mut table = Table::new(&["controller", "chosen", "trials", "traj err(mm)"]);
    for (kind, steps) in [
        (ControllerKind::Pid, 600),
        (ControllerKind::Lqr, 600),
        (ControllerKind::Mpc, 150),
    ] {
        eprintln!("searching {} …", kind.name());
        let out = search(&robot, kind, &req, steps, 11);
        let err = out
            .trials
            .iter()
            .rev()
            .find_map(|(_, _, sim, _)| sim.map(|e| format!("{:.3}", e * 1e3)))
            .unwrap_or_else(|| "-".into());
        table.row(&[
            kind.name().to_string(),
            out.chosen.map(|f| f.label()).unwrap_or_else(|| "none (float)".into()),
            out.trials.len().to_string(),
            err,
        ]);
        // Heuristic ❶: joint evaluation priority (deep joints first).
        eprintln!("  joint priority: {:?}", out.priority);
    }
    table.print("bit-width search results — iiwa, ±0.5 mm tolerance");
    println!(
        "\npaper §V-A: controller-specific formats (PID finest, MPC coarsest);\n\
         FPGA deployment adopts 24-bit (12/12) for iiwa on DSP58."
    );
}
