//! Quickstart: load a robot, evaluate the RBD function suite natively,
//! check the algebraic invariants, and preview the accelerator estimate.
//!
//! Run: `cargo run --release --example quickstart`

use draco::accel::{estimate, Design, RbdFn};
use draco::dynamics::{crba, fd, minv, minv_dd, rnea, rnea_derivatives};
use draco::model::{builtin_robot, State};
use draco::quant::qrbd::quant_rnea;
use draco::quant::QFormat;
use draco::util::rng::Rng;

fn main() {
    let robot = builtin_robot("iiwa").expect("builtin robot");
    let n = robot.dof();
    println!("robot {} — {} DOF", robot.name, n);

    let mut rng = Rng::new(42);
    let s = State::random(&robot, &mut rng);
    let qdd: Vec<f64> = rng.vec_range(n, -2.0, 2.0);

    // Inverse dynamics (ID / RNEA).
    let tau = rnea(&robot, &s.q, &s.qd, &qdd, None);
    println!("\nτ = RNEA(q, q̇, q̈) = {:?}", round3(&tau));

    // Forward dynamics must invert it (paper Eq. 2).
    let back = fd(&robot, &s.q, &s.qd, &tau, None);
    let rt_err = back
        .iter()
        .zip(&qdd)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("FD(ID(q̈)) round-trip max error: {rt_err:.2e}");

    // Analytical M⁻¹ — original vs division-deferring are identical.
    let mi = minv(&robot, &s.q);
    let mi_dd = minv_dd(&robot, &s.q);
    println!("|minv − minv_dd|∞ = {:.2e}", mi.sub(&mi_dd).max_abs());
    let m = crba(&robot, &s.q);
    let ident_err = mi.matmul(&m).sub(&draco::spatial::DMat::identity(n)).max_abs();
    println!("|M⁻¹·M − I|∞ = {ident_err:.2e}");

    // Analytical derivatives (ΔID).
    let (dq, dqd) = rnea_derivatives(&robot, &s.q, &s.qd, &qdd);
    println!("‖∂τ/∂q‖F = {:.3}, ‖∂τ/∂q̇‖F = {:.3}", dq.frobenius(), dqd.frobenius());

    // Quantized evaluation (the paper's 24-bit iiwa format).
    let fmt = QFormat::new(12, 12);
    let tq = quant_rnea(&robot, &s.q, &s.qd, &qdd, fmt);
    let qerr = tau
        .iter()
        .zip(&tq)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("\n24-bit ({}) RNEA max torque deviation: {qerr:.3e} Nm", fmt.label());

    // Accelerator estimate for this robot.
    let design = Design::draco(&robot);
    println!("\nDRACO cycle-model estimates:");
    for f in RbdFn::ALL {
        let p = estimate(&design, &robot, f);
        println!(
            "  {:>4}: latency {:6.2} µs  throughput {:9.0} tasks/s",
            f.name(),
            p.latency_us,
            p.throughput
        );
    }
}

fn round3(xs: &[f64]) -> Vec<f64> {
    xs.iter().map(|x| (x * 1e3).round() / 1e3).collect()
}
