//! END-TO-END driver: all three layers composed on a real workload.
//!
//! A computed-torque controller tracks a reference trajectory on the
//! iiwa. The controller's RNEA evaluations are served REMOTELY: requests
//! flow through the L3 coordinator (router + dynamic batcher) into a
//! PJRT executable compiled from the L2 JAX model whose hot ops are the
//! L1 Pallas kernels. The physics integrates the exact native dynamics.
//!
//! Reported: closed-loop trajectory error (the paper's motion-precision
//! metric) and serving latency/throughput.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_control_loop`

use draco::coordinator::Coordinator;
use draco::model::{builtin_robot, State};
use draco::runtime::artifact::{scan_artifacts, ArtifactFn};
use draco::sim::fk::ee_position;
use draco::sim::integrate::step_semi_implicit;
use draco::sim::traj::Trajectory;
use std::path::Path;
use std::time::Instant;

fn main() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let artifacts: Vec<_> = scan_artifacts(Path::new("artifacts"))
        .into_iter()
        .filter(|a| a.robot == "iiwa" && a.function == ArtifactFn::Rnea && a.batch == 16)
        .collect();
    if artifacts.is_empty() {
        eprintln!("no iiwa rnea artifact found — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("loading {} …", artifacts[0].path.display());
    let coord = Coordinator::start(artifacts, n, 150);

    // Reference: smooth reach + hold.
    let traj = Trajectory::reach(&robot, 0.35, 1.0);
    let dt = 1e-3;
    let steps = 2000;
    let (kp, kd) = (100.0, 20.0);

    let (q0, _, _) = traj.sample(0.0);
    let mut s = State { q: q0, qd: vec![0.0; n] };
    let mut max_tracking_mm: f64 = 0.0;
    let t0 = Instant::now();

    for k in 0..steps {
        let t = k as f64 * dt;
        let (qr, qdr, qddr) = traj.sample(t);
        // PD-shaped desired acceleration, then remote computed torque:
        // τ = RNEA(q, q̇, q̈_des) served by the PJRT executable.
        let v: Vec<f64> = (0..n)
            .map(|i| qddr[i] + kp * (qr[i] - s.q[i]) + kd * (qdr[i] - s.qd[i]))
            .collect();
        let ops: Vec<Vec<f32>> = vec![
            s.q.iter().map(|&x| x as f32).collect(),
            s.qd.iter().map(|&x| x as f32).collect(),
            v.iter().map(|&x| x as f32).collect(),
        ];
        let rx = coord.submit(ArtifactFn::Rnea, ops);
        let tau32 = rx.recv().expect("coordinator alive").expect("execute ok");
        let tau: Vec<f64> = tau32.iter().map(|&x| x as f64).collect();

        step_semi_implicit(&robot, &mut s, &tau, None, dt);

        if k > 1200 {
            // Steady phase: measure Cartesian tracking error.
            let (qr2, _, _) = traj.sample(t + dt);
            let ee = ee_position(&robot, &s.q);
            let ee_ref = ee_position(&robot, &qr2);
            max_tracking_mm = max_tracking_mm.max((ee - ee_ref).norm() * 1e3);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = coord.stats();
    println!("\n=== end-to-end closed loop (iiwa, {steps} steps @ 1 kHz sim time) ===");
    println!("wall time: {:.2} s  ({:.0} control steps/s)", wall, steps as f64 / wall);
    println!(
        "serving: {} requests, {} batches, mean fill {:.0}%, p50 {:.0} µs, p95 {:.0} µs",
        st.completed,
        st.batches,
        st.mean_fill * 100.0,
        st.p50_latency_us,
        st.p95_latency_us
    );
    println!("steady-state end-effector tracking error: {max_tracking_mm:.3} mm");
    coord.shutdown();
    if max_tracking_mm > 2.0 {
        eprintln!("WARN: tracking error above 2 mm — check artifact numerics");
        std::process::exit(1);
    }
    println!("OK: all three layers compose (Pallas→JAX→HLO→PJRT→coordinator→control loop)");
}
