//! Accelerator design-space tour: Fig. 10-style latency/throughput
//! comparison across robots and designs, the division-deferring and
//! DSP-reuse ablations (Fig. 12), and Table-II-style resources.
//!
//! Run: `cargo run --release --example accelerator_sim`

use draco::accel::resources::estimate_resources;
use draco::accel::{estimate, gpu_model, reuse_report, Design, RbdFn};
use draco::model::builtin_robot;
use draco::util::bench::Table;

fn main() {
    for name in ["iiwa", "hyq", "atlas"] {
        let robot = builtin_robot(name).unwrap();
        let designs =
            [Design::draco(&robot), Design::dadu_rbd(&robot), Design::roboshape(&robot)];

        let mut t = Table::new(&["design", "fn", "lat(us)", "tput(tasks/s)", "dsp"]);
        for d in &designs {
            for f in RbdFn::ALL {
                let p = estimate(d, &robot, f);
                t.row(&[
                    d.name.to_string(),
                    f.name().to_string(),
                    format!("{:.2}", p.latency_us),
                    format!("{:.3e}", p.throughput),
                    p.dsp_active.to_string(),
                ]);
            }
        }
        // GPU (GRiD-modeled) rows for context.
        for f in [RbdFn::Id, RbdFn::DeltaFd] {
            let p = gpu_model(&robot, f);
            t.row(&[
                "gpu-grid".into(),
                f.name().to_string(),
                format!("{:.2}", p.latency_us),
                format!("{:.3e}", p.throughput),
                "-".into(),
            ]);
        }
        t.print(&format!("design space — {name}"));

        // Fig. 12(a): division-deferring ablation on Minv.
        let with_dd = estimate(&Design::draco(&robot), &robot, RbdFn::Minv);
        let without = estimate(&Design::draco_no_dd(&robot), &robot, RbdFn::Minv);
        println!(
            "division deferring: Minv latency {:.2} → {:.2} µs ({:.2}x), throughput {:.2}x",
            without.latency_us,
            with_dd.latency_us,
            without.latency_us / with_dd.latency_us,
            with_dd.throughput / without.throughput,
        );

        // Fig. 12(b): inter-module DSP reuse.
        let r = reuse_report(&Design::draco(&robot), &robot);
        println!(
            "DSP reuse: {} DSPs with, {} without → {:.1}% saved (shared {} engines, II {}→{})",
            r.dsp_with,
            r.dsp_without,
            r.savings_frac * 100.0,
            r.shared_engines,
            r.ii_rnea_solo,
            r.ii_composite,
        );

        // Table II resources.
        let res = estimate_resources(&Design::draco(&robot), &robot);
        println!(
            "resources: {} DSP, {}k LUT, {}k FF, {} BRAM, {:.1} W\n",
            res.dsp,
            res.lut / 1000,
            res.ff / 1000,
            res.bram,
            res.power_w
        );
    }
}
